//! Multi-application batch orchestration: one automation cycle, many
//! applications.
//!
//! The ROADMAP's arXiv:2002.09541 evaluation runs *many* applications
//! through the environment-adaptive cycle at once — cheap now that the
//! slot-resolved VM made per-app profiling fast. A [`Batch`] shares one
//! [`Pipeline`] (one `SearchConfig`, one backend, one measurement budget
//! of `max_patterns` per app) across N requests, runs their funnels
//! concurrently on scoped threads, and aggregates the outcomes into a
//! [`BatchReport`] with per-app and cycle-level accounting.
//!
//! Concurrency does not change results: each app's search is
//! deterministic under its seed, so a batch entry is identical to
//! running that app through [`Pipeline::solve`] alone.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::pipeline::{OffloadRequest, Pipeline, Plan, Planned};

/// Outcome of one application in a batch.
#[derive(Debug)]
pub struct BatchEntry {
    pub app: String,
    /// The selected plan, when the app solved.
    pub plan: Option<Plan>,
    pub stored_at: Option<PathBuf>,
    /// Stage-tagged error text, when the app failed.
    pub error: Option<String>,
}

impl BatchEntry {
    pub fn ok(&self) -> bool {
        self.plan.is_some()
    }

    fn cached(&self) -> bool {
        self.plan.as_ref().is_some_and(Plan::is_cached)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("app", Json::Str(self.app.clone())),
            ("ok", Json::Bool(self.ok())),
            ("cached", Json::Bool(self.cached())),
        ];
        match &self.plan {
            Some(plan) => {
                fields.push((
                    "best_pattern",
                    Json::Arr(
                        plan.best_loops()
                            .iter()
                            .map(|&l| Json::Num(l as f64))
                            .collect(),
                    ),
                ));
                fields.push(("speedup", Json::Num(plan.speedup())));
                fields.push((
                    "automation_hours",
                    Json::Num(plan.automation_s() / 3600.0),
                ));
            }
            None => {
                fields.push(("best_pattern", Json::Null));
                fields.push(("speedup", Json::Null));
                fields.push(("automation_hours", Json::Null));
            }
        }
        fields.push((
            "stored_at",
            match &self.stored_at {
                Some(p) => Json::Str(p.display().to_string()),
                None => Json::Null,
            },
        ));
        fields.push((
            "error",
            match &self.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        ));
        Json::obj(fields)
    }
}

/// Aggregate report of one batch automation cycle.
#[derive(Debug)]
pub struct BatchReport {
    pub entries: Vec<BatchEntry>,
    /// Backend that ran the cycle ("fpga", "cpu", ...).
    pub backend: &'static str,
    /// Measurement budget per app (`SearchConfig::max_patterns`).
    pub budget_per_app: usize,
    /// Modeled automation wall clock if the apps ran one after another
    /// on the shared verification environment, seconds.
    pub serial_automation_s: f64,
    /// Modeled automation wall clock with the apps' funnels running
    /// concurrently (the batch's threads): the slowest app bounds the
    /// cycle, seconds.
    pub concurrent_automation_s: f64,
}

impl BatchReport {
    fn new(
        backend: &'static str,
        budget_per_app: usize,
        entries: Vec<BatchEntry>,
    ) -> Self {
        let times: Vec<f64> = entries
            .iter()
            .filter_map(|e| e.plan.as_ref().map(Plan::automation_s))
            .collect();
        BatchReport {
            backend,
            budget_per_app,
            serial_automation_s: times.iter().sum(),
            concurrent_automation_s: times.iter().fold(0.0, |a, &b| a.max(b)),
            entries,
        }
    }

    pub fn solved(&self) -> usize {
        self.entries.iter().filter(|e| e.ok()).count()
    }

    pub fn failed(&self) -> usize {
        self.entries.len() - self.solved()
    }

    pub fn cache_hits(&self) -> usize {
        self.entries.iter().filter(|e| e.cached()).count()
    }

    /// Serialize for `repro batch --out` and downstream tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("apps", Json::Num(self.entries.len() as f64)),
            ("solved", Json::Num(self.solved() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("cache_hits", Json::Num(self.cache_hits() as f64)),
            (
                "budget_per_app",
                Json::Num(self.budget_per_app as f64),
            ),
            (
                "serial_automation_hours",
                Json::Num(self.serial_automation_s / 3600.0),
            ),
            (
                "concurrent_automation_hours",
                Json::Num(self.concurrent_automation_s / 3600.0),
            ),
            (
                "results",
                Json::Arr(
                    self.entries.iter().map(BatchEntry::to_json).collect(),
                ),
            ),
        ])
    }

    /// Write the JSON report to a file.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty()).map_err(|e| {
            anyhow::anyhow!("writing batch report {path:?}: {e}")
        })
    }
}

/// N applications through one shared pipeline (see module docs).
pub struct Batch<'a> {
    pipeline: &'a Pipeline<'a>,
    requests: Vec<OffloadRequest>,
}

impl<'a> Batch<'a> {
    pub fn new(pipeline: &'a Pipeline<'a>) -> Self {
        Batch {
            pipeline,
            requests: Vec::new(),
        }
    }

    pub fn push(&mut self, req: OffloadRequest) {
        self.requests.push(req);
    }

    /// Builder-style [`push`](Self::push).
    pub fn with(mut self, req: OffloadRequest) -> Self {
        self.push(req);
        self
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Run every request through stages 1–5, concurrently. One failing
    /// app does not abort the cycle — its entry carries the error.
    pub fn run(&self) -> BatchReport {
        let results: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .requests
                .iter()
                .map(|req| {
                    let pipe = self.pipeline;
                    let req = req.clone();
                    scope.spawn(move || pipe.solve(req))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("batch worker panicked"))
                .collect()
        });

        let entries = self
            .requests
            .iter()
            .zip(results)
            .map(|(req, res)| match res {
                Ok(Planned {
                    plan, stored_at, ..
                }) => BatchEntry {
                    app: req.app.clone(),
                    plan: Some(plan),
                    stored_at,
                    error: None,
                },
                Err(e) => BatchEntry {
                    app: req.app.clone(),
                    plan: None,
                    stored_at: None,
                    error: Some(e.to_string()),
                },
            })
            .collect();

        BatchReport::new(
            self.pipeline.backend().name(),
            self.pipeline.config().max_patterns,
            entries,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::search::{FpgaBackend, SearchConfig};

    const GOOD: &str = "
#define N 1024
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

    fn backend() -> FpgaBackend<'static> {
        FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        }
    }

    fn req(app: &str, source: &str) -> OffloadRequest {
        OffloadRequest::builder(app)
            .source(source)
            .seed(7)
            .build()
            .unwrap()
    }

    #[test]
    fn batch_isolates_per_app_failures() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let batch = Batch::new(&pipe)
            .with(req("good", GOOD))
            .with(req("noloop", "int main() { return 42; }"));
        assert_eq!(batch.len(), 2);
        assert!(!batch.is_empty());
        let report = batch.run();
        assert_eq!(report.solved(), 1);
        assert_eq!(report.failed(), 1);
        let bad = &report.entries[1];
        assert_eq!(bad.app, "noloop");
        assert!(bad.error.as_ref().unwrap().contains("funnel"));
    }

    #[test]
    fn batch_matches_individual_runs() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let solo = pipe.solve(req("good", GOOD)).unwrap();
        let report = Batch::new(&pipe).with(req("good", GOOD)).run();
        let entry = &report.entries[0];
        let plan = entry.plan.as_ref().unwrap();
        assert_eq!(plan.best_loops(), solo.plan.best_loops());
        assert!((plan.speedup() - solo.plan.speedup()).abs() < 1e-12);
    }

    #[test]
    fn report_json_shape() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let report = Batch::new(&pipe).with(req("good", GOOD)).run();
        let j = report.to_json();
        assert_eq!(j.get(&["apps"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["solved"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["backend"]).unwrap().as_str(), Some("fpga"));
        let results = j.get(&["results"]).unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get(&["app"]).unwrap().as_str(),
            Some("good")
        );
        // Round-trips through the parser.
        let text = j.pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
