//! Multi-application batch orchestration: one automation cycle, many
//! applications — and, in mixed mode, many destinations.
//!
//! The ROADMAP's arXiv:2002.09541 evaluation runs *many* applications
//! through the environment-adaptive cycle at once — cheap now that the
//! slot-resolved VM made per-app profiling fast. A [`Batch`] shares one
//! [`Pipeline`] (one `SearchConfig`, one backend, one measurement budget
//! of `max_patterns` per app) across N requests, runs their funnels
//! concurrently on scoped threads, and aggregates the outcomes into a
//! [`BatchReport`] with per-app and cycle-level accounting.
//!
//! **Mixed destinations** (arXiv:2011.12431): [`Batch::mixed`] registers
//! one pipeline per destination backend. One cycle then measures every
//! app against every destination — reusing each backend's own funnel
//! candidates — and picks the best destination per app by *verified*
//! speedup: the [`BatchEntry`] carries the winning `destination`, the
//! winning plan, and the per-destination [`DestinationOutcome`]s, and the
//! report aggregates the environment's destination split.
//!
//! Concurrency does not change results: each app's search is
//! deterministic under its seed, so a batch entry is identical to
//! running that app through [`Pipeline::solve`] alone on the same
//! backend. A panicking or failing app degrades to an error entry (or a
//! lost destination in mixed mode) — it never aborts the cycle.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::pipeline::{OffloadRequest, Pipeline, Plan, Planned};

/// One destination's result for one application in a mixed cycle.
#[derive(Debug)]
pub struct DestinationOutcome {
    /// Backend name ("fpga", "gpu", "omp", "cpu").
    pub backend: &'static str,
    /// The plan this destination produced, when it solved.
    pub plan: Option<Plan>,
    pub stored_at: Option<PathBuf>,
    /// Stage-tagged error text (or panic message), when it failed.
    pub error: Option<String>,
}

/// Outcome of one application in a batch.
#[derive(Debug)]
pub struct BatchEntry {
    pub app: String,
    /// Winning destination backend, when any destination solved.
    pub destination: Option<&'static str>,
    /// The selected (winning) plan, when the app solved anywhere.
    pub plan: Option<Plan>,
    pub stored_at: Option<PathBuf>,
    /// Combined error text, when every destination failed.
    pub error: Option<String>,
    /// Every measured destination, in backend registration order
    /// (exactly one for a single-backend batch).
    pub outcomes: Vec<DestinationOutcome>,
}

impl BatchEntry {
    pub fn ok(&self) -> bool {
        self.plan.is_some()
    }

    fn cached(&self) -> bool {
        self.plan.as_ref().is_some_and(Plan::is_cached)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("app", Json::Str(self.app.clone())),
            ("ok", Json::Bool(self.ok())),
            ("cached", Json::Bool(self.cached())),
            (
                "destination",
                match self.destination {
                    Some(d) => Json::Str(d.to_string()),
                    None => Json::Null,
                },
            ),
        ];
        match &self.plan {
            Some(plan) => {
                fields.push((
                    "best_pattern",
                    Json::Arr(
                        plan.best_loops()
                            .iter()
                            .map(|&l| Json::Num(l as f64))
                            .collect(),
                    ),
                ));
                fields.push(("speedup", Json::Num(plan.speedup())));
                fields.push((
                    "blocks",
                    Json::Num(plan.block_count() as f64),
                ));
                fields.push((
                    "automation_hours",
                    Json::Num(plan.automation_s() / 3600.0),
                ));
            }
            None => {
                fields.push(("best_pattern", Json::Null));
                fields.push(("speedup", Json::Null));
                fields.push(("blocks", Json::Null));
                fields.push(("automation_hours", Json::Null));
            }
        }
        fields.push((
            "stored_at",
            match &self.stored_at {
                Some(p) => Json::Str(p.display().to_string()),
                None => Json::Null,
            },
        ));
        fields.push((
            "error",
            match &self.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        ));
        // Per-destination speedups (null where that destination failed).
        let mut backends = std::collections::BTreeMap::new();
        for o in &self.outcomes {
            backends.insert(
                o.backend.to_string(),
                match &o.plan {
                    Some(p) => Json::Num(p.speedup()),
                    None => Json::Null,
                },
            );
        }
        fields.push(("backends", Json::Obj(backends)));
        Json::obj(fields)
    }
}

/// Aggregate report of one batch automation cycle.
#[derive(Debug)]
pub struct BatchReport {
    pub entries: Vec<BatchEntry>,
    /// Backend that ran the cycle ("fpga", "cpu", ... — "mixed" for a
    /// multi-destination cycle).
    pub backend: &'static str,
    /// All destination backends measured, in registration order.
    pub backends: Vec<&'static str>,
    /// Measurement budget per app (`SearchConfig::max_patterns`).
    pub budget_per_app: usize,
    /// Modeled automation wall clock if all (app × destination)
    /// measurements ran one after another on the shared verification
    /// environment, seconds.
    pub serial_automation_s: f64,
    /// Modeled automation wall clock with all funnels running
    /// concurrently (the batch's threads): the slowest measurement
    /// bounds the cycle, seconds.
    pub concurrent_automation_s: f64,
}

impl BatchReport {
    fn new(
        backend: &'static str,
        backends: Vec<&'static str>,
        budget_per_app: usize,
        entries: Vec<BatchEntry>,
    ) -> Self {
        let times: Vec<f64> = entries
            .iter()
            .flat_map(|e| e.outcomes.iter())
            .filter_map(|o| o.plan.as_ref().map(Plan::automation_s))
            .collect();
        BatchReport {
            backend,
            backends,
            budget_per_app,
            serial_automation_s: times.iter().sum(),
            concurrent_automation_s: times.iter().fold(0.0, |a, &b| a.max(b)),
            entries,
        }
    }

    pub fn is_mixed(&self) -> bool {
        self.backends.len() > 1
    }

    pub fn solved(&self) -> usize {
        self.entries.iter().filter(|e| e.ok()).count()
    }

    pub fn failed(&self) -> usize {
        self.entries.len() - self.solved()
    }

    pub fn cache_hits(&self) -> usize {
        self.entries.iter().filter(|e| e.cached()).count()
    }

    /// How many apps each destination won, in backend registration
    /// order (destinations that won nothing included with 0).
    pub fn destination_counts(&self) -> Vec<(&'static str, usize)> {
        self.backends
            .iter()
            .map(|&b| {
                let n = self
                    .entries
                    .iter()
                    .filter(|e| e.destination == Some(b))
                    .count();
                (b, n)
            })
            .collect()
    }

    /// Serialize for `repro batch --out` and downstream tooling.
    pub fn to_json(&self) -> Json {
        let mut destinations = std::collections::BTreeMap::new();
        for (b, n) in self.destination_counts() {
            destinations.insert(b.to_string(), Json::Num(n as f64));
        }
        Json::obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("mixed", Json::Bool(self.is_mixed())),
            (
                "backends",
                Json::Arr(
                    self.backends
                        .iter()
                        .map(|b| Json::Str(b.to_string()))
                        .collect(),
                ),
            ),
            ("destinations", Json::Obj(destinations)),
            ("apps", Json::Num(self.entries.len() as f64)),
            ("solved", Json::Num(self.solved() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("cache_hits", Json::Num(self.cache_hits() as f64)),
            (
                "budget_per_app",
                Json::Num(self.budget_per_app as f64),
            ),
            (
                "serial_automation_hours",
                Json::Num(self.serial_automation_s / 3600.0),
            ),
            (
                "concurrent_automation_hours",
                Json::Num(self.concurrent_automation_s / 3600.0),
            ),
            (
                "results",
                Json::Arr(
                    self.entries.iter().map(BatchEntry::to_json).collect(),
                ),
            ),
        ])
    }

    /// Write the JSON report to a file.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty()).map_err(|e| {
            anyhow::anyhow!("writing batch report {path:?}: {e}")
        })
    }
}

/// N applications through one shared pipeline — or through one pipeline
/// per destination in mixed mode (see module docs).
pub struct Batch<'a> {
    pipelines: Vec<&'a Pipeline<'a>>,
    requests: Vec<OffloadRequest>,
}

impl<'a> Batch<'a> {
    /// A single-destination batch (the PR-2 shape): every app measured
    /// on one backend.
    pub fn new(pipeline: &'a Pipeline<'a>) -> Self {
        Batch {
            pipelines: vec![pipeline],
            requests: Vec::new(),
        }
    }

    /// A mixed-destination batch: one pipeline per destination backend.
    /// Every app is measured against every destination, and the best
    /// verified speedup picks its destination. Registration order breaks
    /// ties (put the preferred destination first).
    ///
    /// Routing and the report are keyed by [`crate::search::Backend::name`]
    /// ("fpga", "gpu", "omp", "cpu") — register at most one pipeline per
    /// backend *kind*; two same-kind backends on different boards would
    /// collide in the per-app `backends` map and the destination split.
    pub fn mixed(pipelines: Vec<&'a Pipeline<'a>>) -> Self {
        Batch {
            pipelines,
            requests: Vec::new(),
        }
    }

    pub fn push(&mut self, req: OffloadRequest) {
        self.requests.push(req);
    }

    /// Builder-style [`push`](Self::push).
    pub fn with(mut self, req: OffloadRequest) -> Self {
        self.push(req);
        self
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Destination backends this batch measures, in registration order.
    pub fn backend_names(&self) -> Vec<&'static str> {
        self.pipelines.iter().map(|p| p.backend().name()).collect()
    }

    /// Whether the destination pipelines can share one funnel run per
    /// app: identical search configuration (fingerprint covers every
    /// knob, the execution engine included) and identical narrowing
    /// device. The bundled mixed cycle (fpga+gpu+omp+cpu over one
    /// config, all narrowing on the FPGA resource model) always
    /// qualifies.
    fn sharable(&self) -> bool {
        self.pipelines.len() > 1
            && self.pipelines.windows(2).all(|w| {
                w[0].config().fingerprint() == w[1].config().fingerprint()
                    && w[0].backend().device().name
                        == w[1].backend().device().name
            })
    }

    /// Run every (request × destination) through stages 1–5,
    /// concurrently, then pick each app's destination. In a sharable
    /// mixed cycle, parse / profiling analysis / candidate extraction
    /// run **once per app** and fan out to every destination (only
    /// measurement and selection are per-backend); otherwise each
    /// destination runs its own full funnel. One failing or *panicking*
    /// app does not abort the cycle — its entry carries the error and
    /// the remaining apps still solve.
    pub fn run(&self) -> BatchReport {
        let results: Vec<Vec<Result<Planned, String>>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .requests
                    .iter()
                    .map(|req| scope.spawn(move || self.solve_app(req)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(per_dest) => per_dest,
                        Err(payload) => {
                            // The shared prefix (parse / analysis)
                            // panicked: every destination loses this app.
                            let msg = format!(
                                "worker panicked: {}",
                                panic_message(payload.as_ref())
                            );
                            self.pipelines
                                .iter()
                                .map(|_| Err(msg.clone()))
                                .collect()
                        }
                    })
                    .collect()
            });

        let entries = self
            .requests
            .iter()
            .zip(results)
            .map(|(req, per_app)| {
                let outcomes: Vec<DestinationOutcome> = self
                    .pipelines
                    .iter()
                    .zip(per_app)
                    .map(|(pipe, res)| match res {
                        Ok(Planned {
                            plan, stored_at, ..
                        }) => DestinationOutcome {
                            backend: pipe.backend().name(),
                            plan: Some(plan),
                            stored_at,
                            error: None,
                        },
                        Err(e) => DestinationOutcome {
                            backend: pipe.backend().name(),
                            plan: None,
                            stored_at: None,
                            error: Some(e),
                        },
                    })
                    .collect();
                select_destination(&req.app, outcomes)
            })
            .collect();

        let backends = self.backend_names();
        let label = if backends.len() > 1 {
            "mixed"
        } else {
            backends.first().copied().unwrap_or("none")
        };
        let budget = self
            .pipelines
            .first()
            .map(|p| p.config().max_patterns)
            .unwrap_or(0);
        BatchReport::new(label, backends, budget, entries)
    }

    /// One application across every destination, funnel shared where
    /// the pipelines allow it (see `sharable`).
    fn solve_app(
        &self,
        req: &OffloadRequest,
    ) -> Vec<Result<Planned, String>> {
        if !self.sharable() {
            // Independent full solves, each isolated on its own thread
            // so a panicking backend only loses its own destination.
            return std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .pipelines
                    .iter()
                    .map(|&pipe| {
                        let req = req.clone();
                        scope.spawn(move || pipe.solve(req))
                    })
                    .collect();
                handles.into_iter().map(join_solve).collect()
            });
        }

        // Shared prefix: parse + profiling analysis once per app.
        let first = self.pipelines[0];
        let parsed = match first.parse(req.clone()) {
            Ok(p) => p,
            Err(e) => return self.every_destination_fails(e.to_string()),
        };
        // Per-destination cache lookups against the shared parse.
        let cached: Vec<Result<Option<Planned>, String>> = self
            .pipelines
            .iter()
            .map(|p| p.cached_plan(&parsed).map_err(|e| e.to_string()))
            .collect();
        let all_cached = cached
            .iter()
            .all(|c| matches!(c, Ok(Some(_)) | Err(_)));
        let analyzed = if all_cached {
            None
        } else {
            match first.analyze(parsed) {
                Ok(a) => Some(a),
                Err(e) => {
                    return self.every_destination_fails(e.to_string())
                }
            }
        };
        // Candidate extraction is destination-independent here (shared
        // narrowing device), *unless* the function-block stage is on:
        // block pricing — and therefore the claimed-loop set the funnel
        // must skip — is per-destination. Block detection + sample-test
        // confirmation, however, are destination-independent and run
        // once here even then.
        let shared_cands = match &analyzed {
            Some(a) if !req.func_blocks => {
                match first.extract(a.clone()) {
                    Ok(c) => Some(c),
                    Err(e) => {
                        return self
                            .every_destination_fails(e.to_string())
                    }
                }
            }
            _ => None,
        };
        let shared_blocks = match &analyzed {
            Some(a) if req.func_blocks => {
                Some(first.confirm_blocks(a))
            }
            _ => None,
        };

        std::thread::scope(|scope| {
            let analyzed = &analyzed;
            let shared_cands = &shared_cands;
            let shared_blocks = &shared_blocks;
            let handles: Vec<_> = self
                .pipelines
                .iter()
                .zip(cached)
                .map(|(&pipe, cache_hit)| {
                    scope.spawn(move || match cache_hit {
                        Ok(Some(planned)) => Ok(planned),
                        Err(e) => Err(PipelineErrorText(e)),
                        Ok(None) => {
                            let r = match (shared_cands, shared_blocks) {
                                (Some(c), _) => pipe
                                    .solve_from_candidates(c.clone()),
                                (None, Some(blocks)) => {
                                    let a = analyzed
                                        .as_ref()
                                        .expect("not all cached")
                                        .clone();
                                    pipe.solve_from_blocked(
                                        pipe.price_blocks(a, blocks),
                                    )
                                }
                                (None, None) => pipe.solve_from_analyzed(
                                    analyzed
                                        .as_ref()
                                        .expect("not all cached")
                                        .clone(),
                                ),
                            };
                            r.map_err(|e| PipelineErrorText(e.to_string()))
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(Ok(planned)) => Ok(planned),
                    Ok(Err(PipelineErrorText(e))) => Err(e),
                    Err(payload) => Err(format!(
                        "worker panicked: {}",
                        panic_message(payload.as_ref())
                    )),
                })
                .collect()
        })
    }

    fn every_destination_fails(
        &self,
        msg: String,
    ) -> Vec<Result<Planned, String>> {
        self.pipelines.iter().map(|_| Err(msg.clone())).collect()
    }
}

/// Error text carried across the per-destination worker boundary.
struct PipelineErrorText(String);

fn join_solve(
    h: std::thread::ScopedJoinHandle<
        '_,
        Result<Planned, super::pipeline::PipelineError>,
    >,
) -> Result<Planned, String> {
    match h.join() {
        Ok(Ok(planned)) => Ok(planned),
        Ok(Err(e)) => Err(e.to_string()),
        Err(payload) => Err(format!(
            "worker panicked: {}",
            panic_message(payload.as_ref())
        )),
    }
}

/// Pick the winning destination for one app: verified plans beat
/// unverified ones, then higher speedup wins; earlier registration
/// breaks exact ties.
fn select_destination(
    app: &str,
    outcomes: Vec<DestinationOutcome>,
) -> BatchEntry {
    let mut winner: Option<usize> = None;
    for (i, o) in outcomes.iter().enumerate() {
        let Some(plan) = &o.plan else { continue };
        let better = match winner {
            None => true,
            Some(w) => {
                let best = outcomes[w].plan.as_ref().expect("winner solved");
                (plan.verified_ok() && !best.verified_ok())
                    || (plan.verified_ok() == best.verified_ok()
                        && plan.speedup() > best.speedup())
            }
        };
        if better {
            winner = Some(i);
        }
    }
    match winner {
        Some(i) => BatchEntry {
            app: app.to_string(),
            destination: Some(outcomes[i].backend),
            plan: outcomes[i].plan.clone(),
            stored_at: outcomes[i].stored_at.clone(),
            error: None,
            outcomes,
        },
        None => {
            let error = outcomes
                .iter()
                .map(|o| {
                    format!(
                        "{}: {}",
                        o.backend,
                        o.error.as_deref().unwrap_or("no plan")
                    )
                })
                .collect::<Vec<_>>()
                .join("; ");
            BatchEntry {
                app: app.to_string(),
                destination: None,
                plan: None,
                stored_at: None,
                error: Some(error),
                outcomes,
            }
        }
    }
}

/// Best-effort text of a worker panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
    use crate::gpu::TESLA_T4;
    use crate::hls::ARRIA10_GX;
    use crate::search::{
        Backend, CpuBaseline, FpgaBackend, GpuBackend, OmpBackend,
        SearchConfig,
    };

    const GOOD: &str = "
#define N 1024
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

    fn backend() -> FpgaBackend<'static> {
        FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        }
    }

    fn req(app: &str, source: &str) -> OffloadRequest {
        OffloadRequest::builder(app)
            .source(source)
            .seed(7)
            .build()
            .unwrap()
    }

    #[test]
    fn batch_isolates_per_app_failures() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let batch = Batch::new(&pipe)
            .with(req("good", GOOD))
            .with(req("noloop", "int main() { return 42; }"));
        assert_eq!(batch.len(), 2);
        assert!(!batch.is_empty());
        let report = batch.run();
        assert_eq!(report.solved(), 1);
        assert_eq!(report.failed(), 1);
        let bad = &report.entries[1];
        assert_eq!(bad.app, "noloop");
        assert!(bad.error.as_ref().unwrap().contains("funnel"));
        assert!(bad.destination.is_none());
        let good = &report.entries[0];
        assert_eq!(good.destination, Some("fpga"));
    }

    #[test]
    fn batch_matches_individual_runs() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let solo = pipe.solve(req("good", GOOD)).unwrap();
        let report = Batch::new(&pipe).with(req("good", GOOD)).run();
        let entry = &report.entries[0];
        let plan = entry.plan.as_ref().unwrap();
        assert_eq!(plan.best_loops(), solo.plan.best_loops());
        assert!((plan.speedup() - solo.plan.speedup()).abs() < 1e-12);
    }

    #[test]
    fn report_json_shape() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let report = Batch::new(&pipe).with(req("good", GOOD)).run();
        let j = report.to_json();
        assert_eq!(j.get(&["apps"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["solved"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["backend"]).unwrap().as_str(), Some("fpga"));
        assert_eq!(j.get(&["mixed"]).unwrap().as_bool(), Some(false));
        assert_eq!(
            j.get(&["destinations", "fpga"]).unwrap().as_f64(),
            Some(1.0)
        );
        let results = j.get(&["results"]).unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get(&["app"]).unwrap().as_str(),
            Some("good")
        );
        assert_eq!(
            results[0].get(&["destination"]).unwrap().as_str(),
            Some("fpga")
        );
        assert!(results[0]
            .get(&["backends", "fpga"])
            .unwrap()
            .as_f64()
            .is_some());
        // Round-trips through the parser.
        let text = j.pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    /// A backend that panics while measuring any program with a global
    /// named `boom` — the failure-injection seam for the isolation test.
    struct PanickyBackend<'a>(CpuBaseline<'a>);

    impl Backend for PanickyBackend<'_> {
        fn name(&self) -> &'static str {
            "cpu"
        }

        fn device(&self) -> &crate::hls::Device {
            self.0.device
        }

        fn measure(
            &self,
            prog: &crate::minic::Program,
            analysis: &crate::analysis::Analysis,
            cands: &[crate::search::Candidate],
            pattern: &crate::search::patterns::Pattern,
            cfg: &SearchConfig,
        ) -> Result<
            crate::search::BackendMeasurement,
            crate::search::SearchError,
        > {
            let has_boom = prog.globals.iter().any(|g| {
                matches!(
                    g,
                    crate::minic::ast::Stmt::Decl { name, .. }
                        if name == "boom"
                )
            });
            if has_boom {
                panic!("injected measurement panic");
            }
            self.0.measure(prog, analysis, cands, pattern, cfg)
        }

        fn verify(
            &self,
            prog: &crate::minic::Program,
            cands: &[crate::search::Candidate],
            pattern: &crate::search::patterns::Pattern,
            entry: &str,
            cfg: &SearchConfig,
        ) -> Result<bool, crate::search::SearchError> {
            self.0.verify(prog, cands, pattern, entry, cfg)
        }

        fn deploy_check(
            &self,
            sample: &str,
            env: (&crate::runtime::Runtime, &crate::runtime::Artifacts),
            seed: u64,
        ) -> anyhow::Result<crate::runtime::SampleRun> {
            self.0.deploy_check(sample, env, seed)
        }
    }

    #[test]
    fn panicking_app_degrades_to_an_error_entry() {
        const BOOM: &str = "
#define N 512
float boom[N]; float o[N];
int main() {
    for (int i = 0; i < N; i++) { boom[i] = i * 0.01; }
    for (int i = 0; i < N; i++) { o[i] = sin(boom[i]); }
    return 0;
}";
        let b = PanickyBackend(CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        });
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let report = Batch::new(&pipe)
            .with(req("good", GOOD))
            .with(req("boom", BOOM))
            .run();
        // The panicking app becomes an error entry; the rest still solve.
        assert_eq!(report.solved(), 1);
        assert_eq!(report.failed(), 1);
        let bad = &report.entries[1];
        assert_eq!(bad.app, "boom");
        let err = bad.error.as_ref().unwrap();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("injected measurement panic"), "{err}");
        assert!(report.entries[0].ok());
    }

    /// A second app with a different winner profile, to exercise the
    /// shared-funnel path across more than one request.
    const GOOD2: &str = "
#define N 512
#define REP 8
float x[N]; float y[N];
int main() {
    for (int i = 0; i < N; i++) { x[i] = i * 0.002 - 0.5; }
    for (int r = 0; r < REP; r++) {
        for (int i = 0; i < N; i++) {
            y[i] = sqrt(x[i] * x[i] + 1.0) + sin(x[i]);
        }
    }
    return 0;
}";

    #[test]
    fn shared_funnel_routing_matches_independent_solves() {
        // The mixed cycle shares parse/analysis/extraction per app
        // across the four destination pipelines. Routing and every
        // per-destination figure must be identical to running each
        // (app × backend) solve independently — the PR-3 behavior.
        let fpga = backend();
        let gpu = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &TESLA_T4,
            device: &ARRIA10_GX,
        };
        let omp = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let cpu = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
        let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
        let po = Pipeline::new(SearchConfig::default(), &omp).unwrap();
        let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();
        let batch = Batch::mixed(vec![&pf, &pg, &po, &pc])
            .with(req("good", GOOD))
            .with(req("good2", GOOD2));
        assert!(batch.sharable());
        let report = batch.run();
        assert_eq!(report.solved(), 2);

        for (entry, source) in
            report.entries.iter().zip([GOOD, GOOD2])
        {
            for (outcome, pipe) in
                entry.outcomes.iter().zip([&pf, &pg, &po, &pc])
            {
                let solo = pipe.solve(req(&entry.app, source)).unwrap();
                let shared = outcome.plan.as_ref().unwrap();
                assert_eq!(
                    shared.best_loops(),
                    solo.plan.best_loops(),
                    "{}@{}",
                    entry.app,
                    outcome.backend
                );
                assert!(
                    (shared.speedup() - solo.plan.speedup()).abs()
                        < 1e-12,
                    "{}@{}",
                    entry.app,
                    outcome.backend
                );
            }
            // The winner is whatever an independent comparison picks.
            let best = entry
                .outcomes
                .iter()
                .max_by(|a, b| {
                    a.plan
                        .as_ref()
                        .unwrap()
                        .speedup()
                        .partial_cmp(&b.plan.as_ref().unwrap().speedup())
                        .unwrap()
                })
                .unwrap();
            assert!(
                entry.plan.as_ref().unwrap().speedup() + 1e-12
                    >= best.plan.as_ref().unwrap().speedup()
            );
        }
    }

    #[test]
    fn different_configs_fall_back_to_independent_funnels() {
        let fpga = backend();
        let cpu = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
        let pc = Pipeline::new(
            SearchConfig {
                max_patterns: 5,
                ..Default::default()
            },
            &cpu,
        )
        .unwrap();
        let batch = Batch::mixed(vec![&pf, &pc]).with(req("good", GOOD));
        assert!(!batch.sharable());
        let report = batch.run();
        assert_eq!(report.solved(), 1);
        assert!(report.entries[0]
            .outcomes
            .iter()
            .all(|o| o.plan.is_some()));
    }

    #[test]
    fn mixed_batch_picks_a_destination_per_app() {
        let fpga = backend();
        let gpu = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &TESLA_T4,
            device: &ARRIA10_GX,
        };
        let omp = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let cpu = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
        let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
        let po = Pipeline::new(SearchConfig::default(), &omp).unwrap();
        let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();
        let report = Batch::mixed(vec![&pf, &pg, &po, &pc])
            .with(req("good", GOOD))
            .run();
        assert!(report.is_mixed());
        assert_eq!(report.backend, "mixed");
        assert_eq!(report.backends, vec!["fpga", "gpu", "omp", "cpu"]);
        let entry = &report.entries[0];
        assert_eq!(entry.outcomes.len(), 4);
        // Every destination solved this trivially offloadable app...
        assert!(entry.outcomes.iter().all(|o| o.plan.is_some()));
        // ...and the winner beats (or equals) the all-CPU control. (This
        // tiny trig loop has no PCIe budget at all, so the shared-memory
        // many-core actually takes it.)
        let dest = entry.destination.unwrap();
        assert!(
            dest == "fpga" || dest == "gpu" || dest == "omp",
            "picked {dest}"
        );
        let win = entry.plan.as_ref().unwrap();
        assert!(win.verified_ok());
        for o in &entry.outcomes {
            assert!(
                win.speedup() >= o.plan.as_ref().unwrap().speedup() - 1e-12
            );
        }
        // The winning destination's result is identical to a solo run on
        // that backend alone.
        let solo_pipe = match dest {
            "fpga" => &pf,
            "gpu" => &pg,
            "omp" => &po,
            _ => &pc,
        };
        let solo = solo_pipe.solve(req("good", GOOD)).unwrap();
        assert_eq!(win.best_loops(), solo.plan.best_loops());
        assert!((win.speedup() - solo.plan.speedup()).abs() < 1e-12);
    }
}
