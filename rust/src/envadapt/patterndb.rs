//! Code-pattern DB (paper Fig. 1): persisted offload solutions.
//!
//! Once the verification environment selects a pattern, the solution is
//! stored so production deployment (and later re-adaptation) can reuse
//! it without re-searching. Each record carries the full [`ReuseKey`]
//! it was searched under — source fingerprint, backend, entry function,
//! destination device, and a [`crate::search::SearchConfig`]
//! fingerprint — so the pipeline's plan stage can prove "nothing that
//! shaped this plan has changed" before reusing it instead of
//! re-running the funnel. Records written before a key component
//! existed are missing that field and therefore never match: stale
//! plans degrade to a re-search, never to silent reuse.
//!
//! Storage is the sharded, log-structured [`crate::store`] engine
//! (append-only checksummed shard logs, in-memory index, cost-aware
//! eviction, compaction). [`PatternDb`] and [`PatternIndex`] are thin
//! facades over one shared [`PatternStore`] handle per directory —
//! opening both on the same path costs one replay and gives both the
//! same shard locks and counters. The legacy one-JSON-file-per-app
//! layout is readable only via `repro patterndb migrate`
//! ([`PatternStore::migrate_legacy`]).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::search::OffloadSolution;
use crate::store::PatternStore;
use crate::util::json::Json;

/// Everything a stored plan's validity depends on. All components must
/// match for [`crate::envadapt::Pipeline`] to reuse the record.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ReuseKey {
    /// FNV-1a fingerprint of the application source.
    pub source_hash: u64,
    /// Backend that measured the solution ("fpga", "gpu", "omp", "cpu").
    pub backend: String,
    /// Entry function the solution was profiled and verified under.
    pub entry: String,
    /// Destination device the solution was measured for (the board, not
    /// the funnel-narrowing model) — a plan searched for an Arria10 says
    /// nothing about a T4.
    pub device: String,
    /// [`crate::search::SearchConfig::fingerprint`] at search time.
    pub config_fp: u64,
    /// [`crate::funcblock::Catalog::fingerprint`] when the request ran
    /// with function blocks enabled, 0 for loop-only requests. A plan
    /// whose block replacements came from one catalog must not be
    /// replayed under another (or under a blocks-off request).
    pub catalog_fp: u64,
}

/// Summary of a stored pattern record — enough to reuse the solution
/// without re-measuring (the full measurement JSON stays in the log).
#[derive(Debug, Clone, PartialEq)]
pub struct StoredPattern {
    pub app: String,
    /// Source fingerprint at store time (None for pre-hash records).
    pub source_hash: Option<u64>,
    /// Backend that measured the solution ("fpga", "gpu", "omp", "cpu";
    /// None for pre-hash records). Reuse must not cross backends: a 4x
    /// FPGA plan is not a CPU-baseline plan.
    pub backend: Option<String>,
    /// Entry function the solution was profiled under.
    pub entry: Option<String>,
    /// Destination device name (None for pre-device records, which
    /// never match the reuse check).
    pub device: Option<String>,
    /// Search-config fingerprint (None for pre-fingerprint records,
    /// which never match the reuse check).
    pub config_fp: Option<u64>,
    /// Function-block catalog fingerprint (0 = loop-only request; None
    /// for pre-funcblock records, which never match the reuse check).
    pub catalog_fp: Option<u64>,
    /// Unix seconds when the record was stored (None for pre-age
    /// records). Not part of [`matches`](Self::matches) — age is a
    /// *policy*, enforced by the pipeline's `max_age`, so operators can
    /// tune re-search cadence without invalidating every record. It
    /// *is* what the store's freshness rule and eviction scoring read.
    pub stored_at: Option<u64>,
    /// Offloaded loop ids of the selected pattern.
    pub best_pattern: Vec<u32>,
    /// Function-block replacements stored with the plan.
    pub blocks: u64,
    pub speedup: f64,
    pub automation_hours: f64,
    /// Verification outcome of the selected pattern at store time
    /// (None = verification was off, or a pre-PR-3 record).
    pub verified: Option<bool>,
}

impl StoredPattern {
    /// Whether this record was stored under exactly `key`. Records
    /// missing any component (older schema) never match.
    pub fn matches(&self, key: &ReuseKey) -> bool {
        self.source_hash == Some(key.source_hash)
            && self.backend.as_deref() == Some(key.backend.as_str())
            && self.entry.as_deref() == Some(key.entry.as_str())
            && self.device.as_deref() == Some(key.device.as_str())
            && self.config_fp == Some(key.config_fp)
            && self.catalog_fp == Some(key.catalog_fp)
    }

    /// Record age in seconds at `now` (unix seconds). `None` when the
    /// record predates age stamping — such records count as infinitely
    /// old under any age policy.
    pub fn age_secs(&self, now: u64) -> Option<u64> {
        self.stored_at.map(|t| now.saturating_sub(t))
    }

    /// Parse a record payload (one log record, or a legacy flat file).
    /// `fallback_app` names the record when the payload predates the
    /// `app` field (legacy files are named `<app>.pattern.json`, so the
    /// filename supplies it). `None` when the payload is not a record
    /// object at all.
    pub(crate) fn from_json(
        j: &Json,
        fallback_app: Option<&str>,
    ) -> Option<StoredPattern> {
        let Json::Obj(_) = j else {
            return None;
        };
        let app = j
            .get(&["app"])
            .and_then(Json::as_str)
            .or(fallback_app)?
            .to_string();
        Some(StoredPattern {
            app,
            source_hash: j
                .get(&["source_hash"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            backend: j
                .get(&["backend"])
                .and_then(Json::as_str)
                .map(String::from),
            entry: j
                .get(&["entry"])
                .and_then(Json::as_str)
                .map(String::from),
            device: j
                .get(&["device"])
                .and_then(Json::as_str)
                .map(String::from),
            config_fp: j
                .get(&["config_fp"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            catalog_fp: j
                .get(&["catalog_fp"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            stored_at: j
                .get(&["stored_at"])
                .and_then(Json::as_str)
                .and_then(|s| s.parse().ok()),
            blocks: j
                .get(&["blocks"])
                .and_then(Json::as_arr)
                .map(|arr| arr.len() as u64)
                .unwrap_or(0),
            best_pattern: j
                .get(&["best_pattern"])
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|v| v.as_f64().map(|n| n as u32))
                        .collect()
                })
                .unwrap_or_default(),
            speedup: j
                .get(&["speedup"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            automation_hours: j
                .get(&["automation_hours"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            verified: j.get(&["verified"]).and_then(Json::as_bool),
        })
    }
}

/// Current unix time in whole seconds.
pub(crate) fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// The record payload for a solution — the one schema both the shard
/// logs and the legacy flat files speak. Keyed records additionally
/// carry the reuse key (64-bit hashes as hex strings — they don't
/// survive JSON's f64 numbers) and the `stored_at` stamp; unkeyed
/// records carry neither and are never reused.
pub(crate) fn record_json(
    sol: &OffloadSolution,
    key: Option<&ReuseKey>,
    stamp: u64,
) -> Json {
    let mut j = sol.to_json();
    if let Json::Obj(map) = &mut j {
        // Verification outcome of the *selected* pattern, hoisted to
        // the top level so a cached plan keeps its verified status
        // instead of laundering a failed check into "trusted".
        map.insert(
            "verified".to_string(),
            match sol.best_measurement().verified {
                Some(v) => Json::Bool(v),
                None => Json::Null,
            },
        );
    }
    if let (Json::Obj(map), Some(key)) = (&mut j, key) {
        map.insert(
            "source_hash".to_string(),
            Json::Str(format!("{:016x}", key.source_hash)),
        );
        map.insert("backend".to_string(), Json::Str(key.backend.clone()));
        map.insert("entry".to_string(), Json::Str(key.entry.clone()));
        map.insert("device".to_string(), Json::Str(key.device.clone()));
        map.insert(
            "config_fp".to_string(),
            Json::Str(format!("{:016x}", key.config_fp)),
        );
        map.insert(
            "catalog_fp".to_string(),
            Json::Str(format!("{:016x}", key.catalog_fp)),
        );
        // Age stamp for the re-search policy (unix seconds; decimal
        // string, consistent with the other stamps).
        map.insert("stored_at".to_string(), Json::Str(format!("{stamp}")));
    }
    j
}

/// Pattern store facade: the write/load surface the pipeline and CLI
/// use. Cloning is cheap (an `Arc` bump) and every clone — and every
/// [`PatternIndex`] on the same directory — shares the same underlying
/// [`PatternStore`].
#[derive(Debug, Clone)]
pub struct PatternDb {
    store: Arc<PatternStore>,
}

impl PatternDb {
    /// Open (creating the directory if needed). Re-opening a directory
    /// this process already has open shares the existing handle — no
    /// replay, no second set of locks.
    pub fn open(dir: &Path) -> Result<Self> {
        Ok(PatternDb {
            store: PatternStore::open(dir)?,
        })
    }

    /// Wrap an already-open store handle (tests and benches that need
    /// registry-bypassing [`PatternStore::open_fresh`] semantics).
    pub fn from_store(store: Arc<PatternStore>) -> Self {
        PatternDb { store }
    }

    /// The storage engine underneath (stats, capacity, migration,
    /// compaction live there).
    pub fn store_handle(&self) -> &Arc<PatternStore> {
        &self.store
    }

    /// The shard log an app's records land in (whether or not any
    /// exist yet).
    pub fn path_of(&self, app: &str) -> PathBuf {
        self.store.shard_path_of(app)
    }

    /// Persist a solution (supersedes any previous one for the app).
    /// Records stored this way carry no reuse key and are never reused.
    pub fn store(&self, sol: &OffloadSolution) -> Result<PathBuf> {
        self.store.store_solution(sol, None, unix_now())
    }

    /// Persist a solution together with its full [`ReuseKey`], enabling
    /// cache reuse when source, backend, entry, destination device and
    /// search config are all unchanged.
    pub fn store_hashed(
        &self,
        sol: &OffloadSolution,
        key: &ReuseKey,
    ) -> Result<PathBuf> {
        self.store.store_solution(sol, Some(key), unix_now())
    }

    /// [`store_hashed`](Self::store_hashed) with an explicit
    /// `stored_at` stamp — the testable seam for the concurrent-writer
    /// ordering rule. Keyed appends whose stamp is *older* than the
    /// live record are dropped: when two workers race, the record that
    /// survives is the freshest one, not whichever writer landed last.
    pub(crate) fn write_record_stamped(
        &self,
        sol: &OffloadSolution,
        key: Option<&ReuseKey>,
        stamp: u64,
    ) -> Result<PathBuf> {
        self.store.store_solution(sol, key, stamp)
    }

    /// Rewrite an app's record with a new `stored_at` stamp. The seam
    /// age-policy tests and operators use to age or revive a record
    /// without touching log bytes.
    pub fn restamp(&self, app: &str, stamp: u64) -> Result<bool> {
        self.store.restamp(app, stamp)
    }

    /// Remove an app's record (tombstone append). Returns whether one
    /// was live.
    pub fn remove(&self, app: &str) -> Result<bool> {
        self.store.remove(app)
    }

    /// Load the stored solution JSON for an app, if present.
    pub fn load(&self, app: &str) -> Result<Option<Json>> {
        Ok(self.store.load_json(app))
    }

    /// Load the stored record summary for an app, if present. Corrupt
    /// log damage was already quarantined when the store replayed the
    /// shard logs; a damaged record is simply absent here.
    pub fn load_record(&self, app: &str) -> Result<Option<StoredPattern>> {
        Ok(self.store.get(app))
    }

    /// Apps with stored patterns, sorted.
    pub fn list(&self) -> Result<Vec<String>> {
        Ok(self.store.list())
    }

    /// Quarantined debris for operators to inspect or delete: shard-log
    /// `.corrupt` sidecars, plus legacy `<app>.pattern.json.corrupt`
    /// files (listed by app name, as the flat layout reported them).
    pub fn quarantined(&self) -> Result<Vec<String>> {
        self.store.quarantined()
    }
}

/// Shared in-memory index over a pattern-DB directory — the service
/// tier's hit path. With the sharded store this is the same handle
/// [`PatternDb`] wraps: lookups are a shard-local `RwLock` read + a
/// clone (microseconds, no log I/O), and a cold solve writing some
/// *other* shard can't block them at all.
///
/// Hit/miss counters tally [`lookup`](Self::lookup) outcomes for the
/// service stats surface; they live in the store's
/// [`StoreStats`](crate::store::StoreStats) so every facade on the
/// directory reports the same numbers.
#[derive(Debug)]
pub struct PatternIndex {
    db: PatternDb,
}

impl PatternIndex {
    /// Open the directory (created if needed). First open in the
    /// process replays the shard logs (quarantining damage exactly as
    /// [`PatternStore::open`] documents); subsequent opens are O(1).
    pub fn open(dir: &Path) -> Result<Self> {
        Ok(PatternIndex {
            db: PatternDb::open(dir)?,
        })
    }

    /// Wrap an already-open store handle.
    pub fn from_store(store: Arc<PatternStore>) -> Self {
        PatternIndex {
            db: PatternDb::from_store(store),
        }
    }

    /// The store facade underneath the index.
    pub fn db(&self) -> &PatternDb {
        &self.db
    }

    /// The storage engine itself.
    pub fn store_handle(&self) -> &Arc<PatternStore> {
        self.db.store_handle()
    }

    /// Number of live records.
    pub fn len(&self) -> usize {
        self.db.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reuse-key lookup straight from memory. Counts a hit only when
    /// the record exists *and* matches the full key — a record for the
    /// right app stored under a different backend/config is a miss,
    /// exactly as it would be for [`crate::envadapt::Pipeline`].
    pub fn lookup(
        &self,
        app: &str,
        key: &ReuseKey,
    ) -> Option<StoredPattern> {
        self.db.store.lookup(app, key)
    }

    /// The indexed record for an app, key-blind and counter-free (the
    /// stats surface, not the hit path).
    pub fn get(&self, app: &str) -> Option<StoredPattern> {
        self.db.store.get(app)
    }

    /// All live records, sorted by app.
    pub fn snapshot(&self) -> Vec<StoredPattern> {
        self.db.store.records()
    }

    /// Write-through store: append to the shard log (freshness rule
    /// applies) and publish to the in-memory index in one step. When a
    /// concurrent writer already stored a fresher record, *that* record
    /// is what stays live.
    pub fn store_hashed(
        &self,
        sol: &OffloadSolution,
        key: &ReuseKey,
    ) -> Result<PathBuf> {
        self.db.store_hashed(sol, key)
    }

    /// Re-sync one app's entry from its shard log on disk — the seam
    /// for *external* writers (another process on the same directory).
    /// Only the affected shard is read; the entry is published
    /// atomically, so a concurrent hit sees the old record or the new
    /// one, never a torn state. In-process writers don't need this:
    /// they are write-through.
    pub fn refresh(&self, app: &str) -> Result<()> {
        self.db.store.refresh(app)
    }

    /// Matching lookups served since this directory was opened.
    pub fn hit_count(&self) -> u64 {
        self.db.store.stats().snapshot().hits
    }

    /// Lookups that found no matching record since open.
    pub fn miss_count(&self) -> u64 {
        self.db.store.stats().snapshot().misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{FunnelTrace, PatternMeasurement};
    use crate::store::{log, PatternStore};
    use crate::util::tempdir::TempDir;

    fn dummy_solution(app: &str) -> OffloadSolution {
        OffloadSolution {
            app: app.to_string(),
            funnel: FunnelTrace {
                total_loops: 5,
                offloadable: vec![],
                top_a: vec![],
                reports: vec![],
                top_c: vec![],
            },
            measurements: vec![PatternMeasurement {
                loops: vec![crate::minic::ast::LoopId(2)],
                round: 1,
                timing: crate::fpga::PatternTiming {
                    cpu_baseline_s: 2.0,
                    cpu_rest_s: 0.1,
                    loops: vec![],
                    pattern_s: 0.5,
                    speedup: 4.0,
                    combined: Default::default(),
                },
                compile_s: 10800.0,
                verified: Some(true),
            }],
            best: 0,
            blocks: Vec::new(),
            automation_s: 43200.0,
        }
    }

    fn fresh_db(dir: &TempDir) -> PatternDb {
        PatternDb::from_store(PatternStore::open_fresh(dir.path()).unwrap())
    }

    #[test]
    fn store_and_load_roundtrip() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        db.store(&dummy_solution("demo")).unwrap();
        let loaded = db.load("demo").unwrap().unwrap();
        assert_eq!(loaded.get(&["speedup"]).unwrap().as_f64(), Some(4.0));
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
    }

    #[test]
    fn missing_app_is_none() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        assert!(db.load("nope").unwrap().is_none());
        assert!(db.load_record("nope").unwrap().is_none());
    }

    fn key() -> ReuseKey {
        ReuseKey {
            // A hash beyond f64's 2^53 integer range must survive exactly.
            source_hash: 0xdead_beef_cafe_f00d_u64,
            backend: "fpga".into(),
            entry: "main".into(),
            device: "Intel PAC Arria10 GX 1150".into(),
            config_fp: 0xfeed_face_0123_4567_u64,
            catalog_fp: 0x0bad_cafe_dead_10cc_u64,
        }
    }

    #[test]
    fn hashed_record_roundtrips_the_reuse_key() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, Some(k.source_hash));
        assert_eq!(rec.backend.as_deref(), Some("fpga"));
        assert_eq!(rec.entry.as_deref(), Some("main"));
        assert_eq!(rec.device.as_deref(), Some(k.device.as_str()));
        assert_eq!(rec.config_fp, Some(k.config_fp));
        assert_eq!(rec.catalog_fp, Some(k.catalog_fp));
        assert!(rec.matches(&k));
        assert_eq!(rec.app, "demo");
        assert_eq!(rec.best_pattern, vec![2]);
        assert_eq!(rec.blocks, 0);
        assert_eq!(rec.speedup, 4.0);
        assert!((rec.automation_hours - 12.0).abs() < 1e-9);
        // The selected pattern's verification outcome survives storage.
        assert_eq!(rec.verified, Some(true));
        // The age stamp is present and sane (no time travel).
        let age = rec.age_secs(super::unix_now()).expect("stamped");
        assert!(age < 3600, "record claims to be {age}s old");
    }

    #[test]
    fn record_survives_a_reopen_from_disk() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let k = key();
        fresh_db(&dir).store_hashed(&dummy_solution("demo"), &k).unwrap();
        // A brand-new handle replays the shard logs from scratch.
        let db = fresh_db(&dir);
        let rec = db.load_record("demo").unwrap().unwrap();
        assert!(rec.matches(&k));
        assert_eq!(rec.speedup, 4.0);
    }

    #[test]
    fn any_changed_key_component_misses() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        for changed in [
            ReuseKey { source_hash: 1, ..k.clone() },
            ReuseKey { backend: "gpu".into(), ..k.clone() },
            ReuseKey { entry: "compute".into(), ..k.clone() },
            ReuseKey { device: "NVIDIA Tesla T4".into(), ..k.clone() },
            ReuseKey { config_fp: 2, ..k.clone() },
            ReuseKey { catalog_fp: 3, ..k.clone() },
        ] {
            assert!(!rec.matches(&changed), "{changed:?}");
        }
    }

    #[test]
    fn unhashed_record_has_no_reuse_key_and_never_matches() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        db.store(&dummy_solution("demo")).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, None);
        assert_eq!(rec.backend, None);
        assert_eq!(rec.entry, None);
        assert_eq!(rec.device, None);
        assert_eq!(rec.config_fp, None);
        assert_eq!(rec.catalog_fp, None);
        assert_eq!(rec.stored_at, None);
        assert!(!rec.matches(&key()));
        // Unstamped records count as infinitely old under any policy.
        assert_eq!(rec.age_secs(super::unix_now()), None);
    }

    #[test]
    fn writes_leave_only_shard_logs_behind() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        for entry in std::fs::read_dir(dir.path()).unwrap() {
            let name =
                entry.unwrap().file_name().to_string_lossy().into_owned();
            // Only shard logs — no scratch files, no flat records.
            assert!(
                name.starts_with("shard-") && name.ends_with(".log"),
                "unexpected file {name:?}"
            );
        }
    }

    #[test]
    fn torn_append_is_truncated_and_prior_records_survive() {
        // A crash mid-append leaves a torn frame at the shard log's
        // tail. Reopening truncates the tear and serves every record
        // that was durable before it.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        let shard = db.path_of("demo");
        let full = std::fs::read(&shard).unwrap();
        let mut torn = full.clone();
        torn.extend_from_slice(&full[..full.len() - 3]);
        std::fs::write(&shard, &torn).unwrap();
        drop(db);
        let db = fresh_db(&dir);
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.speedup, 4.0);
        assert!(db.quarantined().unwrap().is_empty());
        assert_eq!(
            db.store_handle().stats().snapshot().torn_truncations,
            1
        );
    }

    #[test]
    fn corrupt_record_is_quarantined_not_fatal() {
        // A record that checksums wrong (bit rot, a hand edit) is moved
        // to the shard's `.corrupt` sidecar and reported absent — the
        // cycle re-searches instead of dying.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        let shard = db.path_of("demo");
        let mut bytes = std::fs::read(&shard).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&shard, &bytes).unwrap();
        drop(db);
        let db = fresh_db(&dir);
        assert!(db.load_record("demo").unwrap().is_none());
        let bad = db.quarantined().unwrap();
        assert_eq!(bad.len(), 1);
        assert!(bad[0].starts_with("shard-"), "{bad:?}");
        assert!(db.list().unwrap().is_empty());
        // A fresh store works again after the quarantine.
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        assert!(db.load_record("demo").unwrap().is_some());
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
    }

    #[test]
    fn pre_funcblock_schema_record_never_matches() {
        // A PR-3-era record: every key component except the catalog
        // fingerprint. It must re-search, never reuse.
        let k = key();
        let Json::Obj(mut map) =
            record_json(&dummy_solution("demo"), Some(&k), 123)
        else {
            panic!("record is an object");
        };
        map.remove("catalog_fp");
        let rec =
            StoredPattern::from_json(&Json::Obj(map), None).unwrap();
        assert_eq!(rec.config_fp, Some(k.config_fp));
        assert!(!rec.matches(&k));
    }

    #[test]
    fn pre_device_schema_record_never_matches() {
        // A PR-2-era record: source_hash + backend + entry but no
        // device / config fingerprint. Re-searched, never reused.
        let k = key();
        let Json::Obj(mut map) =
            record_json(&dummy_solution("demo"), Some(&k), 123)
        else {
            panic!("record is an object");
        };
        map.remove("device");
        map.remove("config_fp");
        let rec =
            StoredPattern::from_json(&Json::Obj(map), None).unwrap();
        assert_eq!(rec.source_hash, Some(k.source_hash));
        assert!(!rec.matches(&k));
    }

    fn dummy_solution_with_speedup(
        app: &str,
        speedup: f64,
    ) -> OffloadSolution {
        let mut sol = dummy_solution(app);
        sol.measurements[0].timing.speedup = speedup;
        sol
    }

    #[test]
    fn older_stamped_write_does_not_clobber_newer_record() {
        // The race this guards: worker A solves, worker B re-solves a
        // moment later, A's write lands *after* B's. The freshness rule
        // drops the stale append on the floor.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        let k = key();
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 8.0),
            Some(&k),
            1_000,
        )
        .unwrap();
        // A late writer with an older stamp: dropped.
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 2.0),
            Some(&k),
            900,
        )
        .unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(1_000));
        assert_eq!(rec.speedup, 8.0);
        assert_eq!(
            db.store_handle().stats().snapshot().stale_writes_dropped,
            1
        );
        // A genuinely fresher writer still wins.
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 3.0),
            Some(&k),
            1_100,
        )
        .unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(1_100));
        assert_eq!(rec.speedup, 3.0);
    }

    #[test]
    fn concurrent_same_app_stores_keep_the_freshest_stamp() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        let k = key();
        std::thread::scope(|s| {
            for i in 0..8u64 {
                let db = db.clone();
                let k = k.clone();
                s.spawn(move || {
                    db.write_record_stamped(
                        &dummy_solution_with_speedup(
                            "demo",
                            i as f64 + 1.0,
                        ),
                        Some(&k),
                        5_000 + i,
                    )
                    .unwrap();
                });
            }
        });
        // Whatever the interleaving, the live record carries the
        // freshest stamp (and that writer's payload) — in memory and
        // after a cold replay.
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(5_007));
        assert_eq!(rec.speedup, 8.0);
        assert!(db.quarantined().unwrap().is_empty());
        drop(db);
        let db = fresh_db(&dir);
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(5_007));
        assert_eq!(rec.speedup, 8.0);
    }

    #[test]
    fn restamp_ages_a_record_in_memory_and_on_disk() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = fresh_db(&dir);
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        assert!(db.restamp("demo", 42).unwrap());
        assert!(!db.restamp("nope", 42).unwrap());
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(42));
        // The restamped record still matches its key…
        assert!(rec.matches(&k));
        // …and the new stamp is durable.
        drop(db);
        let db = fresh_db(&dir);
        assert_eq!(
            db.load_record("demo").unwrap().unwrap().stored_at,
            Some(42)
        );
    }

    #[test]
    fn index_lookup_serves_from_memory_and_counts() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let k = key();
        let idx = PatternIndex::from_store(
            PatternStore::open_fresh(dir.path()).unwrap(),
        );
        assert!(idx.is_empty());
        idx.store_hashed(&dummy_solution("demo"), &k).unwrap();
        assert_eq!(idx.len(), 1);
        // Matching key: a hit, served without touching disk.
        let rec = idx.lookup("demo", &k).expect("indexed");
        assert_eq!(rec.speedup, 4.0);
        // Right app, wrong key: a miss, same as the pipeline's rule.
        let other = ReuseKey { backend: "gpu".into(), ..k.clone() };
        assert!(idx.lookup("demo", &other).is_none());
        assert!(idx.lookup("nope", &k).is_none());
        assert_eq!(idx.hit_count(), 1);
        assert_eq!(idx.miss_count(), 2);
    }

    #[test]
    fn index_refresh_tracks_external_appends_per_shard() {
        // An *external process* appends to the shard log behind the
        // index's back (simulated with a raw framed append). refresh()
        // re-reads just that shard and syncs the one entry — including
        // an external tombstone, which drops it.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        let idx = PatternIndex::from_store(store.clone());
        let k = key();
        idx.store_hashed(&dummy_solution("demo"), &k).unwrap();
        assert_eq!(idx.get("demo").unwrap().speedup, 4.0);

        let external = record_json(
            &dummy_solution_with_speedup("demo", 6.0),
            Some(&k),
            unix_now() + 10,
        );
        log::append(
            &store.shard_path_of("demo"),
            external.pretty().as_bytes(),
        )
        .unwrap();
        // Not visible until refresh — the index is memory-backed.
        assert_eq!(idx.get("demo").unwrap().speedup, 4.0);
        idx.refresh("demo").unwrap();
        assert_eq!(idx.get("demo").unwrap().speedup, 6.0);

        // External tombstone: refresh drops the entry.
        let tomb = Json::obj(vec![("tombstone", Json::Str("demo".into()))]);
        log::append(
            &store.shard_path_of("demo"),
            tomb.pretty().as_bytes(),
        )
        .unwrap();
        idx.refresh("demo").unwrap();
        assert!(idx.get("demo").is_none());
        assert!(idx.is_empty());
    }

    #[test]
    fn refresh_during_concurrent_hits_never_serves_a_torn_record() {
        // Satellite regression: readers hammer the hit path while a
        // writer alternates external appends + refresh. Every observed
        // record must be exactly one of the two valid versions — a
        // half-written or field-mixed record means the index published
        // a torn state.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        let idx = PatternIndex::from_store(store.clone());
        let k = key();
        idx.store_hashed(&dummy_solution_with_speedup("demo", 4.0), &k)
            .unwrap();
        let stop = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while !stop.load(std::sync::atomic::Ordering::Relaxed)
                    {
                        if let Some(rec) = idx.lookup("demo", &k) {
                            // A torn record would break the pairing
                            // between stamp and payload (or the key).
                            assert!(rec.matches(&k));
                            let valid = (rec.speedup == 4.0)
                                || (rec.speedup == 9.0
                                    && rec.stored_at
                                        == Some(9_999_999_999));
                            assert!(
                                valid,
                                "torn record observed: {rec:?}"
                            );
                        }
                    }
                });
            }
            let shard = store.shard_path_of("demo");
            for _ in 0..100 {
                let fresh = record_json(
                    &dummy_solution_with_speedup("demo", 9.0),
                    Some(&k),
                    9_999_999_999,
                );
                log::append(&shard, fresh.pretty().as_bytes()).unwrap();
                idx.refresh("demo").unwrap();
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(idx.get("demo").unwrap().speedup, 9.0);
    }

    #[test]
    fn index_store_keeps_the_fresher_concurrent_record() {
        // Write-through honors the freshness rule: if the store already
        // holds a fresher record, the index keeps *that* record, not
        // the stale write it just attempted.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        let db = PatternDb::from_store(store.clone());
        let idx = PatternIndex::from_store(store);
        let k = key();
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 9.0),
            Some(&k),
            u64::MAX - 1,
        )
        .unwrap();
        idx.store_hashed(&dummy_solution_with_speedup("demo", 1.5), &k)
            .unwrap();
        assert_eq!(idx.get("demo").unwrap().speedup, 9.0);
        assert_eq!(idx.get("demo").unwrap().stored_at, Some(u64::MAX - 1));
    }

    #[test]
    fn eviction_prefers_cheap_stale_records_and_counts() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        store.set_capacity(Some(2));
        let db = PatternDb::from_store(store.clone());
        let k = key();
        let now = unix_now();
        // Expensive+fresh, cheap+ancient, then a third write that
        // overflows capacity: the cheap stale record must be the victim.
        db.write_record_stamped(
            &dummy_solution_with_speedup("keeper", 4.0),
            Some(&k),
            now,
        )
        .unwrap();
        db.write_record_stamped(
            &dummy_solution_with_speedup("victim", 4.0),
            Some(&k),
            now.saturating_sub(30 * 86_400),
        )
        .unwrap();
        db.write_record_stamped(
            &dummy_solution_with_speedup("newcomer", 4.0),
            Some(&k),
            now,
        )
        .unwrap();
        assert_eq!(
            db.list().unwrap(),
            vec!["keeper".to_string(), "newcomer".to_string()]
        );
        let snap = store.stats().snapshot();
        assert_eq!(snap.evictions, 1);
        // Eviction is durable: a cold replay agrees.
        drop((db, store));
        let db = fresh_db(&dir);
        assert_eq!(
            db.list().unwrap(),
            vec!["keeper".to_string(), "newcomer".to_string()]
        );
    }

    #[test]
    fn compaction_reclaims_dead_records_and_preserves_live_state() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        let db = PatternDb::from_store(store.clone());
        let k = key();
        // Many supersedes of one app pile up dead records until the
        // policy (dead >= 8, ratio >= 0.5) rewrites the shard.
        for i in 0..20u64 {
            db.write_record_stamped(
                &dummy_solution_with_speedup("demo", i as f64 + 1.0),
                Some(&k),
                1_000 + i,
            )
            .unwrap();
        }
        let snap = store.stats().snapshot();
        assert!(snap.compactions >= 1, "{snap:?}");
        // Low dead load after compaction, and the freshest record won.
        assert!(store.dead_records() < 8);
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.speedup, 20.0);
        // Durability across a cold replay.
        drop((db, store));
        let db = fresh_db(&dir);
        assert_eq!(db.load_record("demo").unwrap().unwrap().speedup, 20.0);
    }

    #[test]
    fn migrate_legacy_moves_flat_records_into_the_shards() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let k = key();
        // Seed a legacy layout: two flat records + one corrupt file.
        let a = record_json(&dummy_solution("alpha"), Some(&k), 1_000);
        let b = record_json(&dummy_solution("beta"), None, 0);
        std::fs::write(dir.join("alpha.pattern.json"), a.pretty())
            .unwrap();
        std::fs::write(dir.join("beta.pattern.json"), b.pretty()).unwrap();
        std::fs::write(dir.join("bad.pattern.json"), "{\"app\": ").unwrap();

        let store = PatternStore::open_fresh(dir.path()).unwrap();
        let db = PatternDb::from_store(store.clone());
        // Legacy files are invisible until migrated.
        assert!(db.list().unwrap().is_empty());
        assert_eq!(store.legacy_count(), 3);

        let report = store.migrate_legacy().unwrap();
        assert_eq!(report.migrated, 2);
        assert_eq!(report.quarantined, 1);
        assert_eq!(report.skipped_stale, 0);
        assert_eq!(
            db.list().unwrap(),
            vec!["alpha".to_string(), "beta".to_string()]
        );
        let alpha = db.load_record("alpha").unwrap().unwrap();
        assert!(alpha.matches(&k));
        assert_eq!(alpha.stored_at, Some(1_000));
        assert_eq!(db.quarantined().unwrap(), vec!["bad".to_string()]);
        assert_eq!(store.legacy_count(), 0);

        // Idempotent: nothing left to migrate.
        let again = store.migrate_legacy().unwrap();
        assert_eq!(again, crate::store::MigrationReport::default());

        // And durable: a cold replay serves the migrated records.
        drop((db, store));
        let db = fresh_db(&dir);
        assert_eq!(db.list().unwrap().len(), 2);
    }

    #[test]
    fn export_then_migrate_roundtrips() {
        let src = TempDir::new("fpga-offload-pdb-src").unwrap();
        let dst = TempDir::new("fpga-offload-pdb-dst").unwrap();
        let k = key();
        let store = PatternStore::open_fresh(src.path()).unwrap();
        let db = PatternDb::from_store(store.clone());
        db.store_hashed(&dummy_solution("alpha"), &k).unwrap();
        db.store_hashed(&dummy_solution("beta"), &k).unwrap();
        assert_eq!(store.export_legacy(dst.path()).unwrap(), 2);
        // The export is a valid legacy layout: flat-scannable…
        let scanned = PatternStore::scan_legacy(dst.path()).unwrap();
        assert_eq!(scanned.len(), 2);
        assert!(scanned.iter().all(|r| r.matches(&k)));
        // …and migratable into a fresh store.
        let dst_store = PatternStore::open_fresh(dst.path()).unwrap();
        assert_eq!(dst_store.migrate_legacy().unwrap().migrated, 2);
        assert_eq!(
            PatternDb::from_store(dst_store).list().unwrap(),
            vec!["alpha".to_string(), "beta".to_string()]
        );
    }

    #[test]
    fn open_shares_one_handle_per_directory() {
        let dir = TempDir::new("fpga-offload-pdb-reg").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let idx = PatternIndex::open(dir.path()).unwrap();
        // Same engine: a write through one facade is instantly visible
        // (and counted) through the other.
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        assert_eq!(idx.len(), 1);
        assert!(Arc::ptr_eq(db.store_handle(), idx.store_handle()));
    }
}
