//! Code-pattern DB (paper Fig. 1): persisted offload solutions.
//!
//! Once the verification environment selects a pattern, the solution is
//! stored so production deployment (and later re-adaptation) can reuse it
//! without re-searching. File-backed JSON, one file per app. Each record
//! carries the full [`ReuseKey`] it was searched under — source
//! fingerprint, backend, entry function, destination device, and a
//! [`crate::search::SearchConfig`] fingerprint — so the pipeline's plan
//! stage can prove "nothing that shaped this plan has changed" before
//! reusing it instead of re-running the funnel. Records written before a
//! key component existed are missing that field and therefore never
//! match: stale plans degrade to a re-search, never to silent reuse.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::{Context, Result};

use crate::search::OffloadSolution;
use crate::util::json::Json;

/// Everything a stored plan's validity depends on. All components must
/// match for [`crate::envadapt::Pipeline`] to reuse the record.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ReuseKey {
    /// FNV-1a fingerprint of the application source.
    pub source_hash: u64,
    /// Backend that measured the solution ("fpga", "gpu", "omp", "cpu").
    pub backend: String,
    /// Entry function the solution was profiled and verified under.
    pub entry: String,
    /// Destination device the solution was measured for (the board, not
    /// the funnel-narrowing model) — a plan searched for an Arria10 says
    /// nothing about a T4.
    pub device: String,
    /// [`crate::search::SearchConfig::fingerprint`] at search time.
    pub config_fp: u64,
    /// [`crate::funcblock::Catalog::fingerprint`] when the request ran
    /// with function blocks enabled, 0 for loop-only requests. A plan
    /// whose block replacements came from one catalog must not be
    /// replayed under another (or under a blocks-off request).
    pub catalog_fp: u64,
}

/// Summary of a stored pattern record — enough to reuse the solution
/// without re-measuring (the full measurement JSON stays on disk).
#[derive(Debug, Clone, PartialEq)]
pub struct StoredPattern {
    pub app: String,
    /// Source fingerprint at store time (None for pre-hash records).
    pub source_hash: Option<u64>,
    /// Backend that measured the solution ("fpga", "gpu", "omp", "cpu";
    /// None for pre-hash records). Reuse must not cross backends: a 4x
    /// FPGA plan is not a CPU-baseline plan.
    pub backend: Option<String>,
    /// Entry function the solution was profiled under.
    pub entry: Option<String>,
    /// Destination device name (None for pre-device records, which
    /// never match the reuse check).
    pub device: Option<String>,
    /// Search-config fingerprint (None for pre-fingerprint records,
    /// which never match the reuse check).
    pub config_fp: Option<u64>,
    /// Function-block catalog fingerprint (0 = loop-only request; None
    /// for pre-funcblock records, which never match the reuse check).
    pub catalog_fp: Option<u64>,
    /// Unix seconds when the record was stored (None for pre-age
    /// records). Not part of [`matches`](Self::matches) — age is a
    /// *policy*, enforced by the pipeline's `max_age`, so operators can
    /// tune re-search cadence without invalidating every record.
    pub stored_at: Option<u64>,
    /// Offloaded loop ids of the selected pattern.
    pub best_pattern: Vec<u32>,
    /// Function-block replacements stored with the plan.
    pub blocks: u64,
    pub speedup: f64,
    pub automation_hours: f64,
    /// Verification outcome of the selected pattern at store time
    /// (None = verification was off, or a pre-PR-3 record).
    pub verified: Option<bool>,
}

impl StoredPattern {
    /// Whether this record was stored under exactly `key`. Records
    /// missing any component (older schema) never match.
    pub fn matches(&self, key: &ReuseKey) -> bool {
        self.source_hash == Some(key.source_hash)
            && self.backend.as_deref() == Some(key.backend.as_str())
            && self.entry.as_deref() == Some(key.entry.as_str())
            && self.device.as_deref() == Some(key.device.as_str())
            && self.config_fp == Some(key.config_fp)
            && self.catalog_fp == Some(key.catalog_fp)
    }

    /// Record age in seconds at `now` (unix seconds). `None` when the
    /// record predates age stamping — such records count as infinitely
    /// old under any age policy.
    pub fn age_secs(&self, now: u64) -> Option<u64> {
        self.stored_at.map(|t| now.saturating_sub(t))
    }
}

/// Current unix time in whole seconds.
pub(crate) fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Process-wide per-record write lock. Concurrent workers (service
/// worker pool, mixed-batch destinations) storing the same app must not
/// interleave their read-stamp/rename sequences, or a slower writer with
/// an older `stored_at` silently clobbers a fresher record.
fn record_lock(path: &Path) -> Arc<Mutex<()>> {
    static LOCKS: OnceLock<Mutex<HashMap<PathBuf, Arc<Mutex<()>>>>> =
        OnceLock::new();
    let map = LOCKS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = map.lock().unwrap_or_else(|p| p.into_inner());
    guard.entry(path.to_path_buf()).or_default().clone()
}

/// File-backed pattern store.
#[derive(Debug, Clone)]
pub struct PatternDb {
    dir: PathBuf,
}

impl PatternDb {
    /// Open (creating the directory if needed).
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating pattern DB dir {dir:?}"))?;
        Ok(PatternDb {
            dir: dir.to_path_buf(),
        })
    }

    /// Where an app's record lives (whether or not it exists yet).
    pub fn path_of(&self, app: &str) -> PathBuf {
        self.dir.join(format!("{app}.pattern.json"))
    }

    /// Persist a solution (overwrites any previous one for the app).
    /// Records stored this way carry no reuse key and are never reused.
    pub fn store(&self, sol: &OffloadSolution) -> Result<PathBuf> {
        self.write_record(sol, None)
    }

    /// Persist a solution together with its full [`ReuseKey`], enabling
    /// cache reuse when source, backend, entry, destination device and
    /// search config are all unchanged.
    pub fn store_hashed(
        &self,
        sol: &OffloadSolution,
        key: &ReuseKey,
    ) -> Result<PathBuf> {
        self.write_record(sol, Some(key))
    }

    fn write_record(
        &self,
        sol: &OffloadSolution,
        key: Option<&ReuseKey>,
    ) -> Result<PathBuf> {
        self.write_record_stamped(sol, key, unix_now())
    }

    /// [`write_record`](Self::write_record) with an explicit `stored_at`
    /// stamp — the testable seam for the concurrent-writer ordering
    /// rule. Hashed writes are serialized per record path and a write
    /// whose stamp is *older* than the record already on disk is
    /// dropped: when two workers race, the record that survives is the
    /// freshest one, not whichever writer renamed last.
    pub(crate) fn write_record_stamped(
        &self,
        sol: &OffloadSolution,
        key: Option<&ReuseKey>,
        stamp: u64,
    ) -> Result<PathBuf> {
        let path = self.path_of(&sol.app);
        let mut j = sol.to_json();
        if let Json::Obj(map) = &mut j {
            // Verification outcome of the *selected* pattern, hoisted to
            // the top level so a cached plan keeps its verified status
            // instead of laundering a failed check into "trusted".
            map.insert(
                "verified".to_string(),
                match sol.best_measurement().verified {
                    Some(v) => Json::Bool(v),
                    None => Json::Null,
                },
            );
        }
        if let (Json::Obj(map), Some(key)) = (&mut j, key) {
            // 64-bit hashes don't survive JSON's f64 numbers; store hex.
            map.insert(
                "source_hash".to_string(),
                Json::Str(format!("{:016x}", key.source_hash)),
            );
            map.insert(
                "backend".to_string(),
                Json::Str(key.backend.clone()),
            );
            map.insert("entry".to_string(), Json::Str(key.entry.clone()));
            map.insert(
                "device".to_string(),
                Json::Str(key.device.clone()),
            );
            map.insert(
                "config_fp".to_string(),
                Json::Str(format!("{:016x}", key.config_fp)),
            );
            map.insert(
                "catalog_fp".to_string(),
                Json::Str(format!("{:016x}", key.catalog_fp)),
            );
            // Age stamp for the re-search policy (unix seconds; decimal
            // string — the value exceeds f64's exact-integer comfort
            // zone in no plausible timeframe, but stay consistent with
            // the other stamps).
            map.insert(
                "stored_at".to_string(),
                Json::Str(format!("{stamp}")),
            );
        }
        // Crash-safe: write the full record to a per-writer temp file in
        // the same directory, then atomically rename it over the
        // destination. A crash mid-write leaves only a `.tmp` file,
        // which every read path ignores — never a parseable-but-partial
        // record. The temp name carries pid + a process counter so
        // concurrent writers never share a scratch file.
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            "{}.pattern.json.{}-{}.tmp",
            sol.app,
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        // Stamped (hashed) writes serialize per record and respect the
        // freshness rule; unstamped `store()` keeps its documented
        // overwrite-unconditionally semantics.
        if key.is_some() {
            let lock = record_lock(&path);
            let _held = lock.lock().unwrap_or_else(|p| p.into_inner());
            if self.stamp_of(&path) > Some(stamp) {
                return Ok(path);
            }
            std::fs::write(&tmp, j.pretty())
                .with_context(|| format!("writing {tmp:?}"))?;
            std::fs::rename(&tmp, &path).with_context(|| {
                format!("renaming {tmp:?} over {path:?}")
            })?;
        } else {
            std::fs::write(&tmp, j.pretty())
                .with_context(|| format!("writing {tmp:?}"))?;
            std::fs::rename(&tmp, &path).with_context(|| {
                format!("renaming {tmp:?} over {path:?}")
            })?;
        }
        Ok(path)
    }

    /// `stored_at` stamp of the record currently on disk, if it exists,
    /// parses, and is stamped. Any failure reads as "no stamp", which
    /// lets an incoming write proceed.
    fn stamp_of(&self, path: &Path) -> Option<u64> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        j.get(&["stored_at"])
            .and_then(Json::as_str)
            .and_then(|s| s.parse().ok())
    }

    /// Load the stored solution JSON for an app, if present.
    pub fn load(&self, app: &str) -> Result<Option<Json>> {
        let path = self.path_of(app);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        Ok(Some(
            Json::parse(&text).with_context(|| format!("parsing {path:?}"))?,
        ))
    }

    /// Load the stored record summary for an app, if present. A record
    /// that exists but does not parse — a pre-atomic-write crash, disk
    /// corruption, a stray hand edit — is *quarantined*: renamed to
    /// `<app>.pattern.json.corrupt` (out of every read path, preserved
    /// for inspection) and reported as absent rather than failing the
    /// automation cycle.
    pub fn load_record(&self, app: &str) -> Result<Option<StoredPattern>> {
        let path = self.path_of(app);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let j = match Json::parse(&text) {
            Ok(j) => j,
            Err(_) => {
                self.quarantine(&path);
                return Ok(None);
            }
        };
        let record = StoredPattern {
            app: j
                .get(&["app"])
                .and_then(Json::as_str)
                .unwrap_or(app)
                .to_string(),
            source_hash: j
                .get(&["source_hash"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            backend: j
                .get(&["backend"])
                .and_then(Json::as_str)
                .map(String::from),
            entry: j
                .get(&["entry"])
                .and_then(Json::as_str)
                .map(String::from),
            device: j
                .get(&["device"])
                .and_then(Json::as_str)
                .map(String::from),
            config_fp: j
                .get(&["config_fp"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            catalog_fp: j
                .get(&["catalog_fp"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            stored_at: j
                .get(&["stored_at"])
                .and_then(Json::as_str)
                .and_then(|s| s.parse().ok()),
            blocks: j
                .get(&["blocks"])
                .and_then(Json::as_arr)
                .map(|arr| arr.len() as u64)
                .unwrap_or(0),
            best_pattern: j
                .get(&["best_pattern"])
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|v| v.as_f64().map(|n| n as u32))
                        .collect()
                })
                .unwrap_or_default(),
            speedup: j
                .get(&["speedup"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            automation_hours: j
                .get(&["automation_hours"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            verified: j.get(&["verified"]).and_then(Json::as_bool),
        };
        Ok(Some(record))
    }

    /// Move an unparseable record out of every read path. Best effort:
    /// if even the rename fails, the file is removed so a poisoned
    /// record cannot wedge the cycle forever.
    fn quarantine(&self, path: &Path) {
        let mut q = path.as_os_str().to_owned();
        q.push(".corrupt");
        if std::fs::rename(path, &q).is_err() {
            let _ = std::fs::remove_file(path);
        }
    }

    /// Apps with stored patterns.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(app) = name.strip_suffix(".pattern.json") {
                out.push(app.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    /// Apps whose records were quarantined as unparseable — the
    /// `.pattern.json.corrupt` files a failed [`load_record`] leaves
    /// behind, for operators to inspect or delete.
    ///
    /// [`load_record`]: Self::load_record
    pub fn quarantined(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(app) = name.strip_suffix(".pattern.json.corrupt") {
                out.push(app.to_string());
            }
        }
        out.sort();
        Ok(out)
    }
}

/// Shared in-memory index over a [`PatternDb`] directory: every record
/// loaded once at open, then served from memory. This is the service
/// tier's hit path — a reuse-key lookup is a `RwLock` read + a clone,
/// microseconds instead of an open/read/parse of the on-disk JSON per
/// request. Writes go through to disk first (keeping the crash-safe
/// rename and the freshness rule) and then re-read the surviving record
/// into memory, so the index never diverges from what a fresh process
/// would load.
///
/// Hit/miss counters tally [`lookup`](Self::lookup) outcomes for the
/// service stats surface.
#[derive(Debug)]
pub struct PatternIndex {
    db: PatternDb,
    records: RwLock<HashMap<String, StoredPattern>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PatternIndex {
    /// Open the directory (created if needed) and load every parseable
    /// record. Corrupt records quarantine exactly as in
    /// [`PatternDb::load_record`] and simply don't appear in the index.
    pub fn open(dir: &Path) -> Result<Self> {
        let db = PatternDb::open(dir)?;
        let mut records = HashMap::new();
        for app in db.list()? {
            if let Some(rec) = db.load_record(&app)? {
                records.insert(app, rec);
            }
        }
        Ok(PatternIndex {
            db,
            records: RwLock::new(records),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// The file-backed store underneath the index.
    pub fn db(&self) -> &PatternDb {
        &self.db
    }

    /// Number of indexed records.
    pub fn len(&self) -> usize {
        self.read_guard().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn read_guard(
        &self,
    ) -> std::sync::RwLockReadGuard<'_, HashMap<String, StoredPattern>>
    {
        self.records.read().unwrap_or_else(|p| p.into_inner())
    }

    /// Reuse-key lookup straight from memory. Counts a hit only when
    /// the record exists *and* matches the full key — a record for the
    /// right app stored under a different backend/config is a miss,
    /// exactly as it would be for [`crate::envadapt::Pipeline`].
    pub fn lookup(
        &self,
        app: &str,
        key: &ReuseKey,
    ) -> Option<StoredPattern> {
        let guard = self.read_guard();
        match guard.get(app) {
            Some(rec) if rec.matches(key) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(rec.clone())
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// The indexed record for an app, key-blind and counter-free (the
    /// stats surface, not the hit path).
    pub fn get(&self, app: &str) -> Option<StoredPattern> {
        self.read_guard().get(app).cloned()
    }

    /// All indexed records, sorted by app.
    pub fn snapshot(&self) -> Vec<StoredPattern> {
        let mut out: Vec<StoredPattern> =
            self.read_guard().values().cloned().collect();
        out.sort_by(|a, b| a.app.cmp(&b.app));
        out
    }

    /// Write-through store: persist to disk (atomic rename + freshness
    /// rule), then reload the surviving record into memory. When a
    /// concurrent writer already stored a fresher record, *that* record
    /// is what lands in the index.
    pub fn store_hashed(
        &self,
        sol: &OffloadSolution,
        key: &ReuseKey,
    ) -> Result<PathBuf> {
        let path = self.db.store_hashed(sol, key)?;
        self.refresh(&sol.app)?;
        Ok(path)
    }

    /// Re-read one app's record from disk into the index (dropping the
    /// entry if the file is gone or quarantined). The seam for external
    /// writers — a CLI batch run against the same directory, say.
    pub fn refresh(&self, app: &str) -> Result<()> {
        let rec = self.db.load_record(app)?;
        let mut guard =
            self.records.write().unwrap_or_else(|p| p.into_inner());
        match rec {
            Some(rec) => {
                guard.insert(app.to_string(), rec);
            }
            None => {
                guard.remove(app);
            }
        }
        Ok(())
    }

    /// Matching lookups served since open.
    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that found no matching record since open.
    pub fn miss_count(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{FunnelTrace, PatternMeasurement};
    use crate::util::tempdir::TempDir;

    fn dummy_solution(app: &str) -> OffloadSolution {
        OffloadSolution {
            app: app.to_string(),
            funnel: FunnelTrace {
                total_loops: 5,
                offloadable: vec![],
                top_a: vec![],
                reports: vec![],
                top_c: vec![],
            },
            measurements: vec![PatternMeasurement {
                loops: vec![crate::minic::ast::LoopId(2)],
                round: 1,
                timing: crate::fpga::PatternTiming {
                    cpu_baseline_s: 2.0,
                    cpu_rest_s: 0.1,
                    loops: vec![],
                    pattern_s: 0.5,
                    speedup: 4.0,
                    combined: Default::default(),
                },
                compile_s: 10800.0,
                verified: Some(true),
            }],
            best: 0,
            blocks: Vec::new(),
            automation_s: 43200.0,
        }
    }

    #[test]
    fn store_and_load_roundtrip() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store(&dummy_solution("demo")).unwrap();
        let loaded = db.load("demo").unwrap().unwrap();
        assert_eq!(
            loaded.get(&["speedup"]).unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
    }

    #[test]
    fn missing_app_is_none() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        assert!(db.load("nope").unwrap().is_none());
        assert!(db.load_record("nope").unwrap().is_none());
    }

    fn key() -> ReuseKey {
        ReuseKey {
            // A hash beyond f64's 2^53 integer range must survive exactly.
            source_hash: 0xdead_beef_cafe_f00d_u64,
            backend: "fpga".into(),
            entry: "main".into(),
            device: "Intel PAC Arria10 GX 1150".into(),
            config_fp: 0xfeed_face_0123_4567_u64,
            catalog_fp: 0x0bad_cafe_dead_10cc_u64,
        }
    }

    #[test]
    fn hashed_record_roundtrips_the_reuse_key() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, Some(k.source_hash));
        assert_eq!(rec.backend.as_deref(), Some("fpga"));
        assert_eq!(rec.entry.as_deref(), Some("main"));
        assert_eq!(rec.device.as_deref(), Some(k.device.as_str()));
        assert_eq!(rec.config_fp, Some(k.config_fp));
        assert_eq!(rec.catalog_fp, Some(k.catalog_fp));
        assert!(rec.matches(&k));
        assert_eq!(rec.app, "demo");
        assert_eq!(rec.best_pattern, vec![2]);
        assert_eq!(rec.blocks, 0);
        assert_eq!(rec.speedup, 4.0);
        assert!((rec.automation_hours - 12.0).abs() < 1e-9);
        // The selected pattern's verification outcome survives storage.
        assert_eq!(rec.verified, Some(true));
        // The age stamp is present and sane (no time travel).
        let age = rec.age_secs(super::unix_now()).expect("stamped");
        assert!(age < 3600, "record claims to be {age}s old");
    }

    #[test]
    fn any_changed_key_component_misses() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        for changed in [
            ReuseKey { source_hash: 1, ..k.clone() },
            ReuseKey { backend: "gpu".into(), ..k.clone() },
            ReuseKey { entry: "compute".into(), ..k.clone() },
            ReuseKey { device: "NVIDIA Tesla T4".into(), ..k.clone() },
            ReuseKey { config_fp: 2, ..k.clone() },
            ReuseKey { catalog_fp: 3, ..k.clone() },
        ] {
            assert!(!rec.matches(&changed), "{changed:?}");
        }
    }

    #[test]
    fn unhashed_record_has_no_reuse_key_and_never_matches() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store(&dummy_solution("demo")).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, None);
        assert_eq!(rec.backend, None);
        assert_eq!(rec.entry, None);
        assert_eq!(rec.device, None);
        assert_eq!(rec.config_fp, None);
        assert_eq!(rec.catalog_fp, None);
        assert_eq!(rec.stored_at, None);
        assert!(!rec.matches(&key()));
        // Unstamped records count as infinitely old under any policy.
        assert_eq!(rec.age_secs(super::unix_now()), None);
    }

    #[test]
    fn writes_leave_only_the_record_behind() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        let names: Vec<String> = std::fs::read_dir(dir.path())
            .unwrap()
            .map(|e| {
                e.unwrap().file_name().to_string_lossy().into_owned()
            })
            .collect();
        // The temp file was renamed over the destination, not left over.
        assert_eq!(names, vec!["demo.pattern.json".to_string()]);
    }

    #[test]
    fn interrupted_write_is_invisible_to_readers() {
        // A crash mid-write leaves only a partial `.tmp` file (the
        // rename never happened). Every read path must ignore it and
        // keep serving the last complete record.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        let tmp = dir.path().join("demo.pattern.json.tmp");
        std::fs::write(&tmp, "{\"app\": \"demo\", \"speedup\"").unwrap();
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.speedup, 4.0);
        assert!(db.quarantined().unwrap().is_empty());
    }

    #[test]
    fn corrupt_record_is_quarantined_not_fatal() {
        // A record that exists but does not parse (pre-atomic-write
        // crash, corruption) is moved aside and reported absent — the
        // cycle re-searches instead of dying.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        std::fs::write(db.path_of("demo"), "{\"app\": \"demo\",").unwrap();
        assert!(db.load_record("demo").unwrap().is_none());
        assert_eq!(db.quarantined().unwrap(), vec!["demo".to_string()]);
        assert!(db.list().unwrap().is_empty());
        // A fresh store works again after the quarantine.
        db.store_hashed(&dummy_solution("demo"), &key()).unwrap();
        assert!(db.load_record("demo").unwrap().is_some());
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
    }

    #[test]
    fn pre_funcblock_schema_record_never_matches() {
        // Simulate a PR-3-era record: every key component except the
        // catalog fingerprint. It must re-search, never reuse.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let path = db.path_of("demo");
        let text = std::fs::read_to_string(&path).unwrap();
        let Json::Obj(mut map) = Json::parse(&text).unwrap() else {
            panic!("record is an object");
        };
        map.remove("catalog_fp");
        std::fs::write(&path, Json::Obj(map).pretty()).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.config_fp, Some(k.config_fp));
        assert!(!rec.matches(&k));
    }

    #[test]
    fn pre_device_schema_record_never_matches() {
        // Simulate a PR-2-era record: source_hash + backend + entry but
        // no device / config fingerprint. It must be re-searched, never
        // reused.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let path = db.path_of("demo");
        let text = std::fs::read_to_string(&path).unwrap();
        let Json::Obj(mut map) = Json::parse(&text).unwrap() else {
            panic!("record is an object");
        };
        map.remove("device");
        map.remove("config_fp");
        std::fs::write(&path, Json::Obj(map).pretty()).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, Some(k.source_hash));
        assert!(!rec.matches(&k));
    }

    fn dummy_solution_with_speedup(app: &str, speedup: f64) -> OffloadSolution {
        let mut sol = dummy_solution(app);
        sol.measurements[0].timing.speedup = speedup;
        sol
    }

    #[test]
    fn older_stamped_write_does_not_clobber_newer_record() {
        // The race this guards: worker A solves, worker B re-solves a
        // moment later, A's write lands *after* B's. Before the
        // freshness rule, A's rename silently discarded B's fresher
        // record. Now the stale write is dropped on the floor.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 8.0),
            Some(&k),
            1_000,
        )
        .unwrap();
        // A late writer with an older stamp: dropped.
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 2.0),
            Some(&k),
            900,
        )
        .unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(1_000));
        assert_eq!(rec.speedup, 8.0);
        // A genuinely fresher writer still wins.
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 3.0),
            Some(&k),
            1_100,
        )
        .unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(1_100));
        assert_eq!(rec.speedup, 3.0);
    }

    #[test]
    fn concurrent_same_app_stores_keep_the_freshest_stamp() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        std::thread::scope(|s| {
            for i in 0..8u64 {
                let db = db.clone();
                let k = k.clone();
                s.spawn(move || {
                    db.write_record_stamped(
                        &dummy_solution_with_speedup(
                            "demo",
                            i as f64 + 1.0,
                        ),
                        Some(&k),
                        5_000 + i,
                    )
                    .unwrap();
                });
            }
        });
        // Whatever the interleaving, the surviving record parses and
        // carries the freshest stamp (and that writer's payload).
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.stored_at, Some(5_007));
        assert_eq!(rec.speedup, 8.0);
        assert!(db.quarantined().unwrap().is_empty());
        // No stray temp files survive the stampede.
        let leftovers: Vec<String> = std::fs::read_dir(dir.path())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "demo.pattern.json")
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn index_lookup_serves_from_memory_and_counts() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let k = key();
        let idx = PatternIndex::open(dir.path()).unwrap();
        assert!(idx.is_empty());
        idx.store_hashed(&dummy_solution("demo"), &k).unwrap();
        assert_eq!(idx.len(), 1);
        // Matching key: a hit, served without touching disk.
        let rec = idx.lookup("demo", &k).expect("indexed");
        assert_eq!(rec.speedup, 4.0);
        // Right app, wrong key: a miss, same as the pipeline's rule.
        let other = ReuseKey { backend: "gpu".into(), ..k.clone() };
        assert!(idx.lookup("demo", &other).is_none());
        assert!(idx.lookup("nope", &k).is_none());
        assert_eq!(idx.hit_count(), 1);
        assert_eq!(idx.miss_count(), 2);
    }

    #[test]
    fn index_open_loads_existing_records_and_refresh_tracks_disk() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        db.store_hashed(&dummy_solution("demo"), &k).unwrap();
        let idx = PatternIndex::open(dir.path()).unwrap();
        assert_eq!(idx.len(), 1);
        assert!(idx.lookup("demo", &k).is_some());
        // An external writer updates the record; refresh picks it up.
        db.store_hashed(&dummy_solution_with_speedup("demo", 6.0), &k)
            .unwrap();
        assert_eq!(idx.get("demo").unwrap().speedup, 4.0);
        idx.refresh("demo").unwrap();
        assert_eq!(idx.get("demo").unwrap().speedup, 6.0);
        // The file disappears; refresh drops the entry.
        std::fs::remove_file(db.path_of("demo")).unwrap();
        idx.refresh("demo").unwrap();
        assert!(idx.get("demo").is_none());
        assert!(idx.is_empty());
    }

    #[test]
    fn index_store_keeps_the_fresher_concurrent_record() {
        // Write-through honors the freshness rule: if disk already has
        // a fresher record, the index ends up holding *that* record,
        // not the stale write it just attempted.
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        let k = key();
        let idx = PatternIndex::open(dir.path()).unwrap();
        db.write_record_stamped(
            &dummy_solution_with_speedup("demo", 9.0),
            Some(&k),
            u64::MAX - 1,
        )
        .unwrap();
        idx.store_hashed(&dummy_solution_with_speedup("demo", 1.5), &k)
            .unwrap();
        assert_eq!(idx.get("demo").unwrap().speedup, 9.0);
        assert_eq!(idx.get("demo").unwrap().stored_at, Some(u64::MAX - 1));
    }
}
