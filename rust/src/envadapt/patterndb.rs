//! Code-pattern DB (paper Fig. 1): persisted offload solutions.
//!
//! Once the verification environment selects a pattern, the solution is
//! stored so production deployment (and later re-adaptation) can reuse it
//! without re-searching. File-backed JSON, one file per app. Each record
//! carries the FNV-1a fingerprint of the source it was searched for, so
//! the pipeline's plan stage can prove "source unchanged" before reusing
//! a stored pattern instead of re-running the funnel.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::search::OffloadSolution;
use crate::util::json::Json;

/// Summary of a stored pattern record — enough to reuse the solution
/// without re-measuring (the full measurement JSON stays on disk).
#[derive(Debug, Clone, PartialEq)]
pub struct StoredPattern {
    pub app: String,
    /// Source fingerprint at store time (None for pre-hash records).
    pub source_hash: Option<u64>,
    /// Backend that measured the solution ("fpga", "cpu"; None for
    /// pre-hash records). Reuse must not cross backends: a 4x FPGA plan
    /// is not a CPU-baseline plan.
    pub backend: Option<String>,
    /// Entry function the solution was profiled under.
    pub entry: Option<String>,
    /// Offloaded loop ids of the selected pattern.
    pub best_pattern: Vec<u32>,
    pub speedup: f64,
    pub automation_hours: f64,
}

/// File-backed pattern store.
#[derive(Debug, Clone)]
pub struct PatternDb {
    dir: PathBuf,
}

impl PatternDb {
    /// Open (creating the directory if needed).
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating pattern DB dir {dir:?}"))?;
        Ok(PatternDb {
            dir: dir.to_path_buf(),
        })
    }

    /// Where an app's record lives (whether or not it exists yet).
    pub fn path_of(&self, app: &str) -> PathBuf {
        self.dir.join(format!("{app}.pattern.json"))
    }

    /// Persist a solution (overwrites any previous one for the app).
    pub fn store(&self, sol: &OffloadSolution) -> Result<PathBuf> {
        self.write_record(sol, None)
    }

    /// Persist a solution together with its reuse key (source
    /// fingerprint + backend + entry), enabling cache reuse on unchanged
    /// sources measured for the same destination.
    pub fn store_hashed(
        &self,
        sol: &OffloadSolution,
        source_hash: u64,
        backend: &str,
        entry: &str,
    ) -> Result<PathBuf> {
        self.write_record(sol, Some((source_hash, backend, entry)))
    }

    fn write_record(
        &self,
        sol: &OffloadSolution,
        key: Option<(u64, &str, &str)>,
    ) -> Result<PathBuf> {
        let path = self.path_of(&sol.app);
        let mut j = sol.to_json();
        if let (Json::Obj(map), Some((hash, backend, entry))) = (&mut j, key)
        {
            // 64-bit hashes don't survive JSON's f64 numbers; store hex.
            map.insert(
                "source_hash".to_string(),
                Json::Str(format!("{hash:016x}")),
            );
            map.insert("backend".to_string(), Json::Str(backend.into()));
            map.insert("entry".to_string(), Json::Str(entry.into()));
        }
        std::fs::write(&path, j.pretty())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }

    /// Load the stored solution JSON for an app, if present.
    pub fn load(&self, app: &str) -> Result<Option<Json>> {
        let path = self.path_of(app);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        Ok(Some(
            Json::parse(&text).with_context(|| format!("parsing {path:?}"))?,
        ))
    }

    /// Load the stored record summary for an app, if present.
    pub fn load_record(&self, app: &str) -> Result<Option<StoredPattern>> {
        let Some(j) = self.load(app)? else {
            return Ok(None);
        };
        let record = StoredPattern {
            app: j
                .get(&["app"])
                .and_then(Json::as_str)
                .unwrap_or(app)
                .to_string(),
            source_hash: j
                .get(&["source_hash"])
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok()),
            backend: j
                .get(&["backend"])
                .and_then(Json::as_str)
                .map(String::from),
            entry: j
                .get(&["entry"])
                .and_then(Json::as_str)
                .map(String::from),
            best_pattern: j
                .get(&["best_pattern"])
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|v| v.as_f64().map(|n| n as u32))
                        .collect()
                })
                .unwrap_or_default(),
            speedup: j
                .get(&["speedup"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            automation_hours: j
                .get(&["automation_hours"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        };
        Ok(Some(record))
    }

    /// Apps with stored patterns.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(app) = name.strip_suffix(".pattern.json") {
                out.push(app.to_string());
            }
        }
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{FunnelTrace, PatternMeasurement};
    use crate::util::tempdir::TempDir;

    fn dummy_solution(app: &str) -> OffloadSolution {
        OffloadSolution {
            app: app.to_string(),
            funnel: FunnelTrace {
                total_loops: 5,
                offloadable: vec![],
                top_a: vec![],
                reports: vec![],
                top_c: vec![],
            },
            measurements: vec![PatternMeasurement {
                loops: vec![crate::minic::ast::LoopId(2)],
                round: 1,
                timing: crate::fpga::PatternTiming {
                    cpu_baseline_s: 2.0,
                    cpu_rest_s: 0.1,
                    loops: vec![],
                    pattern_s: 0.5,
                    speedup: 4.0,
                    combined: Default::default(),
                },
                compile_s: 10800.0,
                verified: Some(true),
            }],
            best: 0,
            automation_s: 43200.0,
        }
    }

    #[test]
    fn store_and_load_roundtrip() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store(&dummy_solution("demo")).unwrap();
        let loaded = db.load("demo").unwrap().unwrap();
        assert_eq!(
            loaded.get(&["speedup"]).unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
    }

    #[test]
    fn missing_app_is_none() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        assert!(db.load("nope").unwrap().is_none());
        assert!(db.load_record("nope").unwrap().is_none());
    }

    #[test]
    fn hashed_record_roundtrips_the_reuse_key() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        // A hash beyond f64's 2^53 integer range must survive exactly.
        let hash = 0xdead_beef_cafe_f00d_u64;
        db.store_hashed(&dummy_solution("demo"), hash, "fpga", "main")
            .unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, Some(hash));
        assert_eq!(rec.backend.as_deref(), Some("fpga"));
        assert_eq!(rec.entry.as_deref(), Some("main"));
        assert_eq!(rec.app, "demo");
        assert_eq!(rec.best_pattern, vec![2]);
        assert_eq!(rec.speedup, 4.0);
        assert!((rec.automation_hours - 12.0).abs() < 1e-9);
    }

    #[test]
    fn unhashed_record_has_no_reuse_key() {
        let dir = TempDir::new("fpga-offload-pdb").unwrap();
        let db = PatternDb::open(dir.path()).unwrap();
        db.store(&dummy_solution("demo")).unwrap();
        let rec = db.load_record("demo").unwrap().unwrap();
        assert_eq!(rec.source_hash, None);
        assert_eq!(rec.backend, None);
        assert_eq!(rec.entry, None);
    }
}
