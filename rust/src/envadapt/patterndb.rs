//! Code-pattern DB (paper Fig. 1): persisted offload solutions.
//!
//! Once the verification environment selects a pattern, the solution is
//! stored so production deployment (and later re-adaptation) can reuse it
//! without re-searching. File-backed JSON, one file per app.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::search::OffloadSolution;
use crate::util::json::Json;

/// File-backed pattern store.
#[derive(Debug, Clone)]
pub struct PatternDb {
    dir: PathBuf,
}

impl PatternDb {
    /// Open (creating the directory if needed).
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating pattern DB dir {dir:?}"))?;
        Ok(PatternDb {
            dir: dir.to_path_buf(),
        })
    }

    fn path_for(&self, app: &str) -> PathBuf {
        self.dir.join(format!("{app}.pattern.json"))
    }

    /// Persist a solution (overwrites any previous one for the app).
    pub fn store(&self, sol: &OffloadSolution) -> Result<PathBuf> {
        let path = self.path_for(&sol.app);
        std::fs::write(&path, sol.to_json().pretty())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }

    /// Load the stored solution summary for an app, if present.
    pub fn load(&self, app: &str) -> Result<Option<Json>> {
        let path = self.path_for(app);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        Ok(Some(
            Json::parse(&text).with_context(|| format!("parsing {path:?}"))?,
        ))
    }

    /// Apps with stored patterns.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(app) = name.strip_suffix(".pattern.json") {
                out.push(app.to_string());
            }
        }
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{FunnelTrace, PatternMeasurement};

    fn dummy_solution(app: &str) -> OffloadSolution {
        OffloadSolution {
            app: app.to_string(),
            funnel: FunnelTrace {
                total_loops: 5,
                offloadable: vec![],
                top_a: vec![],
                reports: vec![],
                top_c: vec![],
            },
            measurements: vec![PatternMeasurement {
                loops: vec![crate::minic::ast::LoopId(2)],
                round: 1,
                timing: crate::fpga::PatternTiming {
                    cpu_baseline_s: 2.0,
                    cpu_rest_s: 0.1,
                    loops: vec![],
                    pattern_s: 0.5,
                    speedup: 4.0,
                    combined: Default::default(),
                },
                compile_s: 10800.0,
                verified: Some(true),
            }],
            best: 0,
            automation_s: 43200.0,
        }
    }

    #[test]
    fn store_and_load_roundtrip() {
        let dir = std::env::temp_dir().join("fpga_offload_pdb_test");
        std::fs::remove_dir_all(&dir).ok();
        let db = PatternDb::open(&dir).unwrap();
        db.store(&dummy_solution("demo")).unwrap();
        let loaded = db.load("demo").unwrap().unwrap();
        assert_eq!(
            loaded.get(&["speedup"]).unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(db.list().unwrap(), vec!["demo".to_string()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_app_is_none() {
        let dir = std::env::temp_dir().join("fpga_offload_pdb_test2");
        std::fs::remove_dir_all(&dir).ok();
        let db = PatternDb::open(&dir).unwrap();
        assert!(db.load("nope").unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
