//! The one-call flow (paper Fig. 1) — now a thin shim over the staged
//! [`Pipeline`].
//!
//! Historically this module *was* the API: `run_flow` ran all six steps
//! behind one opaque call. The staged pipeline in [`super::pipeline`]
//! replaced it — each Fig.-1 step is a typed stage there (step 1
//! [`Pipeline::parse`] + [`Pipeline::analyze`], steps 2–3
//! [`Pipeline::extract`], step 4 [`Pipeline::measure`], step 5
//! [`Pipeline::select`], step 6 [`Pipeline::deploy`]) — and `run_flow`
//! remains only so existing callers and tests keep working. New code
//! should build a [`Pipeline`] (and a [`super::batch::Batch`] for many
//! applications) directly.

use std::path::Path;

use anyhow::{Context, Result};

use crate::analysis::{analyze, Analysis};
use crate::cpu::CpuModel;
use crate::hls::Device;
use crate::minic::{parse, typecheck, Program};
use crate::runtime::{Artifacts, Runtime, SampleRun};
use crate::search::{FpgaBackend, OffloadSolution, SearchConfig};

use super::pipeline::{OffloadRequest, Pipeline, Plan};
use super::testdb::{TestCase, TestDb};

/// Everything the flow produced for one application.
#[derive(Debug)]
pub struct FlowReport {
    pub app: String,
    pub solution: OffloadSolution,
    /// Where the pattern was stored (step 5), if a DB dir was given.
    pub stored_at: Option<std::path::PathBuf>,
    /// PJRT sample-test result (step 6), if the app has an artifact and a
    /// runtime was supplied.
    pub sample_run: Option<SampleRun>,
}

/// Options for a flow run.
pub struct FlowOptions<'a> {
    pub config: SearchConfig,
    pub cpu: &'a CpuModel,
    pub device: &'a Device,
    /// Pattern-DB directory (None = don't persist).
    pub pattern_db: Option<&'a Path>,
    /// PJRT runtime + artifacts for the step-6 sample test (None = skip).
    pub runtime: Option<(&'a Runtime, &'a Artifacts)>,
    pub seed: u64,
}

/// Step 1 only: parse + semantic check + analysis.
pub fn analyze_source(source: &str, entry: &str) -> Result<(Program, Analysis)> {
    let prog = parse(source).map_err(|e| anyhow::anyhow!("{e}"))?;
    typecheck::check_ok(&prog).map_err(|e| anyhow::anyhow!("{e}"))?;
    let analysis =
        analyze(&prog, entry).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok((prog, analysis))
}

/// Run the full flow for one application.
///
/// Deprecated shim: builds a [`Pipeline`] on an [`FpgaBackend`] and runs
/// the six stages exactly as the staged API would (cache reuse off, so
/// behavior matches the original always-search flow).
#[deprecated(
    since = "0.2.0",
    note = "use envadapt::Pipeline (stages) or envadapt::Batch (many apps)"
)]
pub fn run_flow(
    app: &str,
    source: &str,
    testdb: &TestDb,
    opts: &FlowOptions<'_>,
) -> Result<FlowReport> {
    let case: &TestCase = testdb
        .get(app)
        .with_context(|| format!("no test case registered for {app:?}"))?;

    let backend = FpgaBackend {
        cpu: opts.cpu,
        device: opts.device,
    };
    let mut pipeline = Pipeline::new(opts.config.clone(), &backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(dir) = opts.pattern_db {
        pipeline = pipeline.with_pattern_db(dir);
    }

    let mut req = OffloadRequest::from_case(case, source);
    req.seed = opts.seed;

    let deployed = pipeline
        .run(req, opts.runtime)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let solution = match deployed.plan {
        Plan::Fresh(sol) => sol,
        Plan::Cached(_) => {
            anyhow::bail!("unexpected cached plan in run_flow")
        }
    };
    Ok(FlowReport {
        app: deployed.app,
        solution,
        stored_at: deployed.stored_at,
        sample_run: deployed.sample_run,
    })
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::util::tempdir::TempDir;

    const SRC: &str = "
#define N 1024
float a[N]; float outr[N]; float outi[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.002 - 1.0; }
    for (int i = 0; i < N; i++) { outr[i] = sin(a[i]) * cos(a[i]); }
    for (int i = 0; i < N; i++) { outi[i] = sqrt(a[i] * a[i] + 1.0); }
    return 0;
}";

    #[test]
    fn flow_without_runtime_or_db() {
        let mut testdb = TestDb::new();
        testdb.register(TestCase {
            app: "mini".into(),
            entry: "main".into(),
            observed_arrays: vec!["outr".into(), "outi".into()],
            pjrt_sample: None,
            description: "unit test app".into(),
        });
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: None,
            runtime: None,
            seed: 1,
        };
        let report = run_flow("mini", SRC, &testdb, &opts).unwrap();
        assert!(report.solution.speedup() > 0.5);
        assert!(report.stored_at.is_none());
        assert!(report.sample_run.is_none());
    }

    #[test]
    fn flow_persists_to_pattern_db() {
        let dir = TempDir::new("fpga-offload-flow").unwrap();
        let mut testdb = TestDb::new();
        testdb.register(TestCase {
            app: "mini".into(),
            entry: "main".into(),
            observed_arrays: vec![],
            pjrt_sample: None,
            description: String::new(),
        });
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: Some(dir.path()),
            runtime: None,
            seed: 1,
        };
        let report = run_flow("mini", SRC, &testdb, &opts).unwrap();
        assert!(report.stored_at.as_ref().unwrap().exists());
        let db = super::super::patterndb::PatternDb::open(dir.path()).unwrap();
        assert!(db.load("mini").unwrap().is_some());
        // The shim stores hash-carrying records like the pipeline does.
        let rec = db.load_record("mini").unwrap().unwrap();
        assert_eq!(
            rec.source_hash,
            Some(super::super::pipeline::source_fingerprint(SRC))
        );
    }

    #[test]
    fn flow_rejects_unregistered_app() {
        let testdb = TestDb::new();
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: None,
            runtime: None,
            seed: 1,
        };
        assert!(run_flow("ghost", SRC, &testdb, &opts).is_err());
    }

    #[test]
    fn flow_rejects_malformed_source() {
        let mut testdb = TestDb::new();
        testdb.register(TestCase {
            app: "bad".into(),
            entry: "main".into(),
            observed_arrays: vec![],
            pjrt_sample: None,
            description: String::new(),
        });
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: None,
            runtime: None,
            seed: 1,
        };
        assert!(run_flow("bad", "int main( {", &testdb, &opts).is_err());
    }
}
