//! The environment-adaptive-software flow (paper Fig. 1).
//!
//! Steps, as the paper numbers them:
//! 1. **Code analysis** — parse + typecheck + loop extraction + profiling.
//! 2. **Extraction of offloadable areas** — candidate filtering and the
//!    intensity / resource-efficiency funnel.
//! 3. **Conversion** — OpenCL-style kernel/host generation (inside the
//!    funnel) and pattern generation.
//! 4. **Verification-environment measurement** — simulate + functionally
//!    verify each pattern, two rounds.
//! 5. **Solution selection + DB store** — best pattern into the
//!    code-pattern DB.
//! 6. **Production deployment check** — the PJRT sample test: execute the
//!    application's real kernels (Pallas→HLO artifacts) and validate
//!    numerics, proving the deployable stack end to end.

use std::path::Path;

use anyhow::{Context, Result};

use crate::analysis::{analyze, analyze_with, Analysis};
use crate::cpu::CpuModel;
use crate::hls::Device;
use crate::minic::{parse, typecheck, Program};
use crate::runtime::{self, Artifacts, Runtime, SampleRun};
use crate::search::{search, OffloadSolution, SearchConfig};

use super::patterndb::PatternDb;
use super::testdb::{TestCase, TestDb};

/// Everything the flow produced for one application.
#[derive(Debug)]
pub struct FlowReport {
    pub app: String,
    pub solution: OffloadSolution,
    /// Where the pattern was stored (step 5), if a DB dir was given.
    pub stored_at: Option<std::path::PathBuf>,
    /// PJRT sample-test result (step 6), if the app has an artifact and a
    /// runtime was supplied.
    pub sample_run: Option<SampleRun>,
}

/// Options for a flow run.
pub struct FlowOptions<'a> {
    pub config: SearchConfig,
    pub cpu: &'a CpuModel,
    pub device: &'a Device,
    /// Pattern-DB directory (None = don't persist).
    pub pattern_db: Option<&'a Path>,
    /// PJRT runtime + artifacts for the step-6 sample test (None = skip).
    pub runtime: Option<(&'a Runtime, &'a Artifacts)>,
    pub seed: u64,
}

/// Step 1 only: parse + semantic check + analysis.
pub fn analyze_source(source: &str, entry: &str) -> Result<(Program, Analysis)> {
    let prog = parse(source).map_err(|e| anyhow::anyhow!("{e}"))?;
    typecheck::check_ok(&prog).map_err(|e| anyhow::anyhow!("{e}"))?;
    let analysis =
        analyze(&prog, entry).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok((prog, analysis))
}

/// Run the full flow for one application.
pub fn run_flow(
    app: &str,
    source: &str,
    testdb: &TestDb,
    opts: &FlowOptions<'_>,
) -> Result<FlowReport> {
    let case: &TestCase = testdb
        .get(app)
        .with_context(|| format!("no test case registered for {app:?}"))?;

    // Steps 1–2: analysis (profiling runs on the configured engine).
    let prog = parse(source).map_err(|e| anyhow::anyhow!("{e}"))?;
    typecheck::check_ok(&prog).map_err(|e| anyhow::anyhow!("{e}"))?;
    let analysis = analyze_with(&prog, &case.entry, opts.config.engine)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Steps 3–5: funnel, patterns, measurement, selection.
    let solution = search(
        app,
        &prog,
        &analysis,
        &opts.config,
        opts.cpu,
        opts.device,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Step 5: persist to the code-pattern DB.
    let stored_at = match opts.pattern_db {
        Some(dir) => Some(PatternDb::open(dir)?.store(&solution)?),
        None => None,
    };

    // Step 6: PJRT sample test — run the real (Pallas→HLO) kernels.
    let sample_run = match (&case.pjrt_sample, opts.runtime) {
        (Some(sample), Some((rt, art))) => Some(
            runtime::run_app(rt, art, sample, opts.seed)
                .context("PJRT sample test failed")?,
        ),
        _ => None,
    };

    Ok(FlowReport {
        app: app.to_string(),
        solution,
        stored_at,
        sample_run,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;

    const SRC: &str = "
#define N 1024
float a[N]; float outr[N]; float outi[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.002 - 1.0; }
    for (int i = 0; i < N; i++) { outr[i] = sin(a[i]) * cos(a[i]); }
    for (int i = 0; i < N; i++) { outi[i] = sqrt(a[i] * a[i] + 1.0); }
    return 0;
}";

    #[test]
    fn flow_without_runtime_or_db() {
        let mut testdb = TestDb::new();
        testdb.register(TestCase {
            app: "mini".into(),
            entry: "main".into(),
            observed_arrays: vec!["outr".into(), "outi".into()],
            pjrt_sample: None,
            description: "unit test app".into(),
        });
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: None,
            runtime: None,
            seed: 1,
        };
        let report = run_flow("mini", SRC, &testdb, &opts).unwrap();
        assert!(report.solution.speedup() > 0.5);
        assert!(report.stored_at.is_none());
        assert!(report.sample_run.is_none());
    }

    #[test]
    fn flow_persists_to_pattern_db() {
        let dir = std::env::temp_dir().join("fpga_offload_flow_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut testdb = TestDb::new();
        testdb.register(TestCase {
            app: "mini".into(),
            entry: "main".into(),
            observed_arrays: vec![],
            pjrt_sample: None,
            description: String::new(),
        });
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: Some(&dir),
            runtime: None,
            seed: 1,
        };
        let report = run_flow("mini", SRC, &testdb, &opts).unwrap();
        assert!(report.stored_at.as_ref().unwrap().exists());
        let db = PatternDb::open(&dir).unwrap();
        assert!(db.load("mini").unwrap().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flow_rejects_unregistered_app() {
        let testdb = TestDb::new();
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: None,
            runtime: None,
            seed: 1,
        };
        assert!(run_flow("ghost", SRC, &testdb, &opts).is_err());
    }

    #[test]
    fn flow_rejects_malformed_source() {
        let mut testdb = TestDb::new();
        testdb.register(TestCase {
            app: "bad".into(),
            entry: "main".into(),
            observed_arrays: vec![],
            pjrt_sample: None,
            description: String::new(),
        });
        let opts = FlowOptions {
            config: SearchConfig::default(),
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
            pattern_db: None,
            runtime: None,
            seed: 1,
        };
        assert!(run_flow("bad", "int main( {", &testdb, &opts).is_err());
    }
}
