//! Test-case DB (paper Fig. 1): which sample test proves an application's
//! performance and correctness.
//!
//! The paper's flow keeps test cases in a DB (Jenkins-style) so the
//! verification environment can run "the sample processing specified by
//! the application". Here: app name → entry function + expected arrays +
//! optional PJRT sample-test id (the real-kernel numeric probe).
//!
//! On-disk snapshots share the pattern store's checksummed frame format
//! ([`crate::store::log`]): [`TestDb::save`] writes one frame per case
//! atomically, [`TestDb::load`] reads back only checksum-clean frames.

use std::collections::BTreeMap;
use std::path::Path;

use crate::store::log;
use crate::util::json::Json;
use anyhow::Result;

/// One registered test case.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TestCase {
    pub app: String,
    /// MiniC entry function for the all-CPU baseline + verification runs.
    pub entry: String,
    /// Global arrays whose contents define the observable output.
    pub observed_arrays: Vec<String>,
    /// PJRT sample-test id (`tdfir` / `mriq`) when the application has an
    /// AOT artifact; None for CPU-only verification.
    pub pjrt_sample: Option<String>,
    pub description: String,
}

/// In-memory registry with JSON round-trip for persistence.
#[derive(Debug, Default, Clone)]
pub struct TestDb {
    cases: BTreeMap<String, TestCase>,
}

impl TestDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// The registry preloaded with the paper's evaluated applications.
    pub fn builtin() -> Self {
        let mut db = Self::new();
        db.register(TestCase {
            app: "tdfir".into(),
            entry: "main".into(),
            observed_arrays: vec!["outr".into(), "outi".into()],
            pjrt_sample: Some("tdfir".into()),
            description: "HPEC time-domain FIR filter bank sample test"
                .into(),
        });
        db.register(TestCase {
            app: "mriq".into(),
            entry: "main".into(),
            observed_arrays: vec!["qr".into(), "qi".into()],
            pjrt_sample: Some("mriq".into()),
            description: "Parboil MRI-Q Q-matrix sample test".into(),
        });
        db.register(TestCase {
            app: "sobel".into(),
            entry: "main".into(),
            observed_arrays: vec!["gmag".into()],
            pjrt_sample: None,
            description: "Sobel edge-detection sample test (IoT camera \
                          motivation, paper §1)"
                .into(),
        });
        db
    }

    pub fn register(&mut self, case: TestCase) {
        self.cases.insert(case.app.clone(), case);
    }

    pub fn get(&self, app: &str) -> Option<&TestCase> {
        self.cases.get(app)
    }

    pub fn apps(&self) -> Vec<&str> {
        self.cases.keys().map(String::as_str).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.cases.values().map(case_json).collect())
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        let mut db = Self::new();
        for item in v.as_arr()? {
            db.register(case_from_json(item)?);
        }
        Some(db)
    }

    /// Snapshot the registry to `path`: one checksummed frame per case,
    /// replaced atomically via the pattern store's log writer.
    pub fn save(&self, path: &Path) -> Result<()> {
        let payloads: Vec<Vec<u8>> = self
            .cases
            .values()
            .map(|c| case_json(c).to_string().into_bytes())
            .collect();
        let refs: Vec<&[u8]> =
            payloads.iter().map(Vec::as_slice).collect();
        log::write_atomic(path, &refs)
    }

    /// Load a snapshot written by [`TestDb::save`]. Only frames whose
    /// checksums hold are read; a missing file loads as empty.
    pub fn load(path: &Path) -> Result<Self> {
        let mut db = Self::new();
        for payload in log::read_frames(path)? {
            let Ok(text) = String::from_utf8(payload) else {
                continue;
            };
            let Ok(json) = Json::parse(&text) else {
                continue;
            };
            if let Some(case) = case_from_json(&json) {
                db.register(case);
            }
        }
        Ok(db)
    }
}

fn case_json(c: &TestCase) -> Json {
    Json::obj(vec![
        ("app", Json::Str(c.app.clone())),
        ("entry", Json::Str(c.entry.clone())),
        (
            "observed_arrays",
            Json::Arr(
                c.observed_arrays
                    .iter()
                    .map(|a| Json::Str(a.clone()))
                    .collect(),
            ),
        ),
        (
            "pjrt_sample",
            c.pjrt_sample
                .clone()
                .map(Json::Str)
                .unwrap_or(Json::Null),
        ),
        ("description", Json::Str(c.description.clone())),
    ])
}

fn case_from_json(item: &Json) -> Option<TestCase> {
    Some(TestCase {
        app: item.get(&["app"])?.as_str()?.to_string(),
        entry: item.get(&["entry"])?.as_str()?.to_string(),
        observed_arrays: item
            .get(&["observed_arrays"])?
            .as_arr()?
            .iter()
            .filter_map(|a| a.as_str().map(String::from))
            .collect(),
        pjrt_sample: item
            .get(&["pjrt_sample"])
            .and_then(Json::as_str)
            .map(String::from),
        description: item.get(&["description"])?.as_str()?.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_has_paper_apps() {
        let db = TestDb::builtin();
        assert!(db.get("tdfir").is_some());
        assert!(db.get("mriq").is_some());
        assert_eq!(
            db.get("tdfir").unwrap().pjrt_sample.as_deref(),
            Some("tdfir")
        );
    }

    #[test]
    fn json_roundtrip() {
        let db = TestDb::builtin();
        let j = db.to_json();
        let back = TestDb::from_json(&j).unwrap();
        assert_eq!(db.apps(), back.apps());
        assert_eq!(db.get("mriq"), back.get("mriq"));
    }

    #[test]
    fn sobel_is_cpu_only() {
        let db = TestDb::builtin();
        assert!(db.get("sobel").unwrap().pjrt_sample.is_none());
    }

    #[test]
    fn save_load_roundtrips() {
        let dir = crate::util::tempdir::TempDir::new("testdb").unwrap();
        let path = dir.join("cases.db");
        let db = TestDb::builtin();
        db.save(&path).unwrap();
        let back = TestDb::load(&path).unwrap();
        assert_eq!(db.apps(), back.apps());
        assert_eq!(db.get("tdfir"), back.get("tdfir"));
    }

    #[test]
    fn torn_tail_keeps_checksum_clean_cases() {
        let dir =
            crate::util::tempdir::TempDir::new("testdb-torn").unwrap();
        let path = dir.join("cases.db");
        TestDb::builtin().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&path, &bytes).unwrap();
        let back = TestDb::load(&path).unwrap();
        // The torn final frame is dropped; everything before it loads.
        assert_eq!(back.apps().len(), TestDb::builtin().apps().len() - 1);
    }
}
