//! Facility-resource DB (paper Fig. 1 / Fig. 3): the machines the
//! environment-adaptive software can deploy to.
//!
//! Mirrors the paper's experiment environment: a verification machine and
//! a running (production) environment, both Dell R740 + Xeon Bronze 3104
//! + Intel PAC Arria10 GX, plus the client note PC that submits code.
//!
//! Persistence rides the pattern store's checksummed frame format
//! ([`crate::store::log`]): [`FacilityDb::save`] snapshots the inventory
//! as one framed record per facility via an atomic rename, and
//! [`FacilityDb::load`] reads back only frames whose checksums hold — a
//! torn tail just means the previous save survives.

use std::path::Path;

use crate::cpu::{CpuModel, XEON_BRONZE_3104};
use crate::hls::{Device, ARRIA10_GX};
use crate::store::log;
use crate::util::json::Json;
use anyhow::Result;

/// Role of a facility in the flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Try-and-error measurement machine.
    Verification,
    /// Production environment the tuned code deploys to.
    Running,
    /// Submits application code; no accelerator.
    Client,
}

/// One facility record.
#[derive(Debug, Clone)]
pub struct Facility {
    pub name: String,
    pub role: Role,
    pub hardware: String,
    pub os: String,
    pub cpu: Option<CpuModel>,
    pub fpga: Option<Device>,
    /// Concurrent FPGA compile slots.
    pub build_slots: usize,
}

/// The facility inventory.
#[derive(Debug, Clone, Default)]
pub struct FacilityDb {
    pub facilities: Vec<Facility>,
}

impl FacilityDb {
    /// The paper's Fig. 3 environment.
    pub fn paper_fig3() -> Self {
        FacilityDb {
            facilities: vec![
                Facility {
                    name: "verification".into(),
                    role: Role::Verification,
                    hardware: "Dell PowerEdge R740".into(),
                    os: "CentOS 7.4".into(),
                    cpu: Some(XEON_BRONZE_3104),
                    fpga: Some(ARRIA10_GX),
                    build_slots: 1,
                },
                Facility {
                    name: "running".into(),
                    role: Role::Running,
                    hardware: "Dell PowerEdge R740".into(),
                    os: "CentOS 7.4".into(),
                    cpu: Some(XEON_BRONZE_3104),
                    fpga: Some(ARRIA10_GX),
                    build_slots: 0,
                },
                Facility {
                    name: "client".into(),
                    role: Role::Client,
                    hardware: "HP ProBook 470 G3".into(),
                    os: "Windows 7 Professional".into(),
                    cpu: None,
                    fpga: None,
                    build_slots: 0,
                },
            ],
        }
    }

    pub fn verification(&self) -> Option<&Facility> {
        self.facilities.iter().find(|f| f.role == Role::Verification)
    }

    pub fn running(&self) -> Option<&Facility> {
        self.facilities.iter().find(|f| f.role == Role::Running)
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.facilities.iter().map(facility_json).collect())
    }

    /// Snapshot the inventory to `path`: one checksummed frame per
    /// facility, written atomically (scratch file + rename) via the
    /// pattern store's log writer.
    pub fn save(&self, path: &Path) -> Result<()> {
        let payloads: Vec<Vec<u8>> = self
            .facilities
            .iter()
            .map(|f| facility_json(f).to_string().into_bytes())
            .collect();
        let refs: Vec<&[u8]> =
            payloads.iter().map(Vec::as_slice).collect();
        log::write_atomic(path, &refs)
    }

    /// Load a snapshot written by [`FacilityDb::save`]. Frames that fail
    /// their checksum (and everything after them) are ignored; a missing
    /// file loads as an empty inventory.
    pub fn load(path: &Path) -> Result<Self> {
        let mut db = FacilityDb::default();
        for payload in log::read_frames(path)? {
            let Ok(text) = String::from_utf8(payload) else {
                continue;
            };
            let Ok(json) = Json::parse(&text) else {
                continue;
            };
            if let Some(f) = facility_from_json(&json) {
                db.facilities.push(f);
            }
        }
        Ok(db)
    }
}

fn facility_json(f: &Facility) -> Json {
    Json::obj(vec![
        ("name", Json::Str(f.name.clone())),
        (
            "role",
            Json::Str(
                match f.role {
                    Role::Verification => "verification",
                    Role::Running => "running",
                    Role::Client => "client",
                }
                .into(),
            ),
        ),
        ("hardware", Json::Str(f.hardware.clone())),
        ("os", Json::Str(f.os.clone())),
        (
            "cpu",
            f.cpu
                .as_ref()
                .map(|c| Json::Str(c.name.into()))
                .unwrap_or(Json::Null),
        ),
        (
            "fpga",
            f.fpga
                .as_ref()
                .map(|d| Json::Str(d.name.into()))
                .unwrap_or(Json::Null),
        ),
        ("build_slots", Json::Num(f.build_slots as f64)),
    ])
}

/// Rebuild a facility from its snapshot JSON. Hardware models are
/// resolved back to the bundled statics by name; an unrecognized name
/// degrades to `None` rather than failing the load.
fn facility_from_json(j: &Json) -> Option<Facility> {
    let role = match j.get(&["role"])?.as_str()? {
        "verification" => Role::Verification,
        "running" => Role::Running,
        "client" => Role::Client,
        _ => return None,
    };
    let cpu = j
        .get(&["cpu"])
        .and_then(Json::as_str)
        .filter(|n| *n == XEON_BRONZE_3104.name)
        .map(|_| XEON_BRONZE_3104);
    let fpga = j
        .get(&["fpga"])
        .and_then(Json::as_str)
        .filter(|n| *n == ARRIA10_GX.name)
        .map(|_| ARRIA10_GX);
    Some(Facility {
        name: j.get(&["name"])?.as_str()?.to_string(),
        role,
        hardware: j.get(&["hardware"])?.as_str()?.to_string(),
        os: j.get(&["os"])?.as_str()?.to_string(),
        cpu,
        fpga,
        build_slots: j.get(&["build_slots"])?.as_f64()? as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_inventory_complete() {
        let db = FacilityDb::paper_fig3();
        assert_eq!(db.facilities.len(), 3);
        let v = db.verification().unwrap();
        assert!(v.fpga.is_some());
        assert_eq!(v.build_slots, 1);
        assert!(db.running().is_some());
    }

    #[test]
    fn json_has_roles() {
        let j = FacilityDb::paper_fig3().to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr
            .iter()
            .any(|f| f.get(&["role"]).unwrap().as_str() == Some("client")));
    }

    #[test]
    fn save_load_roundtrips_with_hardware_statics() {
        let dir = crate::util::tempdir::TempDir::new("facdb").unwrap();
        let path = dir.join("facilities.db");
        let db = FacilityDb::paper_fig3();
        db.save(&path).unwrap();
        let back = FacilityDb::load(&path).unwrap();
        assert_eq!(back.facilities.len(), 3);
        let v = back.verification().unwrap();
        assert_eq!(v.cpu.as_ref().unwrap().name, XEON_BRONZE_3104.name);
        assert_eq!(v.fpga.as_ref().unwrap().name, ARRIA10_GX.name);
        assert_eq!(v.build_slots, 1);
        assert!(back
            .facilities
            .iter()
            .any(|f| f.role == Role::Client && f.cpu.is_none()));
    }

    #[test]
    fn torn_tail_loads_the_previous_save() {
        let dir = crate::util::tempdir::TempDir::new("facdb-torn").unwrap();
        let path = dir.join("facilities.db");
        FacilityDb::paper_fig3().save(&path).unwrap();
        // Simulate a crash mid-append: garbage half-frame at the tail.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[7u8; 5]);
        std::fs::write(&path, bytes).unwrap();
        let back = FacilityDb::load(&path).unwrap();
        assert_eq!(back.facilities.len(), 3);
    }

    #[test]
    fn missing_file_loads_empty() {
        let dir = crate::util::tempdir::TempDir::new("facdb-miss").unwrap();
        let db = FacilityDb::load(&dir.join("nope.db")).unwrap();
        assert!(db.facilities.is_empty());
    }
}
