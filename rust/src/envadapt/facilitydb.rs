//! Facility-resource DB (paper Fig. 1 / Fig. 3): the machines the
//! environment-adaptive software can deploy to.
//!
//! Mirrors the paper's experiment environment: a verification machine and
//! a running (production) environment, both Dell R740 + Xeon Bronze 3104
//! + Intel PAC Arria10 GX, plus the client note PC that submits code.

use crate::cpu::{CpuModel, XEON_BRONZE_3104};
use crate::hls::{Device, ARRIA10_GX};
use crate::util::json::Json;

/// Role of a facility in the flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Try-and-error measurement machine.
    Verification,
    /// Production environment the tuned code deploys to.
    Running,
    /// Submits application code; no accelerator.
    Client,
}

/// One facility record.
#[derive(Debug, Clone)]
pub struct Facility {
    pub name: String,
    pub role: Role,
    pub hardware: String,
    pub os: String,
    pub cpu: Option<CpuModel>,
    pub fpga: Option<Device>,
    /// Concurrent FPGA compile slots.
    pub build_slots: usize,
}

/// The facility inventory.
#[derive(Debug, Clone, Default)]
pub struct FacilityDb {
    pub facilities: Vec<Facility>,
}

impl FacilityDb {
    /// The paper's Fig. 3 environment.
    pub fn paper_fig3() -> Self {
        FacilityDb {
            facilities: vec![
                Facility {
                    name: "verification".into(),
                    role: Role::Verification,
                    hardware: "Dell PowerEdge R740".into(),
                    os: "CentOS 7.4".into(),
                    cpu: Some(XEON_BRONZE_3104),
                    fpga: Some(ARRIA10_GX),
                    build_slots: 1,
                },
                Facility {
                    name: "running".into(),
                    role: Role::Running,
                    hardware: "Dell PowerEdge R740".into(),
                    os: "CentOS 7.4".into(),
                    cpu: Some(XEON_BRONZE_3104),
                    fpga: Some(ARRIA10_GX),
                    build_slots: 0,
                },
                Facility {
                    name: "client".into(),
                    role: Role::Client,
                    hardware: "HP ProBook 470 G3".into(),
                    os: "Windows 7 Professional".into(),
                    cpu: None,
                    fpga: None,
                    build_slots: 0,
                },
            ],
        }
    }

    pub fn verification(&self) -> Option<&Facility> {
        self.facilities.iter().find(|f| f.role == Role::Verification)
    }

    pub fn running(&self) -> Option<&Facility> {
        self.facilities.iter().find(|f| f.role == Role::Running)
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.facilities
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("name", Json::Str(f.name.clone())),
                        (
                            "role",
                            Json::Str(
                                match f.role {
                                    Role::Verification => "verification",
                                    Role::Running => "running",
                                    Role::Client => "client",
                                }
                                .into(),
                            ),
                        ),
                        ("hardware", Json::Str(f.hardware.clone())),
                        ("os", Json::Str(f.os.clone())),
                        (
                            "fpga",
                            f.fpga
                                .as_ref()
                                .map(|d| Json::Str(d.name.into()))
                                .unwrap_or(Json::Null),
                        ),
                        ("build_slots", Json::Num(f.build_slots as f64)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_inventory_complete() {
        let db = FacilityDb::paper_fig3();
        assert_eq!(db.facilities.len(), 3);
        let v = db.verification().unwrap();
        assert!(v.fpga.is_some());
        assert_eq!(v.build_slots, 1);
        assert!(db.running().is_some());
    }

    #[test]
    fn json_has_roles() {
        let j = FacilityDb::paper_fig3().to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr
            .iter()
            .any(|f| f.get(&["role"]).unwrap().as_str() == Some("client")));
    }
}
