//! Environment-adaptive software (paper Fig. 1): the flow and its DBs.
//!
//! * [`flow`] — steps 1–6 end to end for one application.
//! * [`testdb`] — test-case DB (sample tests per app).
//! * [`patterndb`] — code-pattern DB (persisted solutions).
//! * [`facilitydb`] — facility-resource DB (Fig. 3 machines).

pub mod facilitydb;
pub mod flow;
pub mod patterndb;
pub mod testdb;

pub use facilitydb::{Facility, FacilityDb, Role};
pub use flow::{analyze_source, run_flow, FlowOptions, FlowReport};
pub use patterndb::PatternDb;
pub use testdb::{TestCase, TestDb};
