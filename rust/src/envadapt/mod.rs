//! Environment-adaptive software (paper Fig. 1): the staged offload
//! pipeline, batch orchestration, and the flow's DBs.
//!
//! * [`pipeline`] — the typed, staged API: `OffloadRequest` →
//!   `Parsed → Analyzed → Candidates → Measured → Planned → Deployed`,
//!   one stage per Fig.-1 step, measurement routed through a
//!   [`crate::search::Backend`].
//! * [`batch`] — N applications through one shared pipeline per
//!   automation cycle, funnels running concurrently; in mixed mode one
//!   pipeline per destination backend (FPGA / GPU / many-core OpenMP /
//!   CPU), with the best verified speedup picking each app's
//!   destination.
//! * [`flow`] — the legacy one-call `run_flow`, now a shim over the
//!   pipeline.
//! * [`testdb`] — test-case DB (sample tests per app).
//! * [`patterndb`] — code-pattern DB (persisted solutions, source-hash
//!   stamped for reuse).
//! * [`facilitydb`] — facility-resource DB (Fig. 3 machines).
//!
//! Requests are built (and validated) before any stage runs:
//!
//! ```
//! use fpga_offload::envadapt::OffloadRequest;
//!
//! let req = OffloadRequest::builder("app")
//!     .source("int main() { return 0; }")
//!     .entry("main")
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! assert_eq!(req.app, "app");
//! // A request without source never reaches the pipeline.
//! assert!(OffloadRequest::builder("app").build().is_err());
//! ```

pub mod batch;
pub mod facilitydb;
pub mod flow;
pub mod patterndb;
pub mod pipeline;
pub mod testdb;

pub use batch::{
    Batch, BatchEntry, BatchReport, DestinationOutcome, ServiceLevel,
};
pub use facilitydb::{Facility, FacilityDb, Role};
pub use flow::{analyze_source, FlowOptions, FlowReport};
#[allow(deprecated)]
pub use flow::run_flow;
pub use patterndb::{PatternDb, PatternIndex, ReuseKey, StoredPattern};
pub use pipeline::{
    source_fingerprint, Analyzed, Candidates, Deployed, FuncBlocked,
    Measured, OffloadRequest, OffloadRequestBuilder, Parsed, Pipeline,
    PipelineError, Plan, Planned,
};
pub use testdb::{TestCase, TestDb};
