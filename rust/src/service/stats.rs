//! Service telemetry: lock-free counters plus log-bucketed latency
//! histograms for p50/p99.
//!
//! Latencies land in a [`LogHistogram`] per class — wait-free
//! `fetch_add`s into log-linear buckets (exact below 128µs, ≤1/64
//! relative error above), so the hit path never takes a lock to record
//! its own latency and memory stays bounded at any request rate. The
//! same snapshots feed the JSON `stats` op, the Prometheus-text
//! `metrics` op, and `repro client --stats`.
//!
//! The snapshot also carries the retry seam's [`FaultReport`] — the
//! per-stage retry/timeout/panic/backoff tallies that PR 6 collected
//! per batch cycle but the service tier used to drop on the floor
//! (every job built a fresh `FaultStats`). The service now threads one
//! shared `FaultStats` through every worker pipeline and surfaces it
//! here.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::{HistogramSnapshot, LogHistogram, PromText};
use crate::search::FaultReport;
use crate::store::StoreStatsSnapshot;
use crate::util::json::Json;

/// Shared, thread-safe service counters. One instance lives in the
/// service; every worker and caller thread updates it directly.
#[derive(Debug, Default)]
pub struct ServiceStats {
    requests: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    rejected: AtomicU64,
    timeouts: AtomicU64,
    degraded: AtomicU64,
    solves: AtomicU64,
    solve_errors: AtomicU64,
    solve_us_total: AtomicU64,
    refreshes_scheduled: AtomicU64,
    refreshes_dropped: AtomicU64,
    refreshes_done: AtomicU64,
    hit_latency: LogHistogram,
    miss_latency: LogHistogram,
}

impl ServiceStats {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn request(&self) {
        Self::bump(&self.requests);
    }

    pub(crate) fn hit(&self, latency_us: u64) {
        Self::bump(&self.hits);
        self.hit_latency.record(latency_us);
    }

    pub(crate) fn miss(&self, latency_us: u64) {
        Self::bump(&self.misses);
        self.miss_latency.record(latency_us);
    }

    pub(crate) fn coalesced(&self) {
        Self::bump(&self.coalesced);
    }

    pub(crate) fn rejected(&self) {
        Self::bump(&self.rejected);
    }

    pub(crate) fn timeout(&self) {
        Self::bump(&self.timeouts);
    }

    pub(crate) fn degraded(&self) {
        Self::bump(&self.degraded);
    }

    pub(crate) fn solve(&self, solve_us: u64, failed: bool) {
        Self::bump(&self.solves);
        self.solve_us_total.fetch_add(solve_us, Ordering::Relaxed);
        if failed {
            Self::bump(&self.solve_errors);
        }
    }

    pub(crate) fn refresh_scheduled(&self) {
        Self::bump(&self.refreshes_scheduled);
    }

    pub(crate) fn refresh_dropped(&self) {
        Self::bump(&self.refreshes_dropped);
    }

    pub(crate) fn refresh_done(&self) {
        Self::bump(&self.refreshes_done);
    }

    /// Mean worker solve time so far, milliseconds (the retry-hint
    /// input). A fallback guess before any solve has completed.
    pub(crate) fn avg_solve_ms(&self) -> f64 {
        let solves = self.solves.load(Ordering::Relaxed);
        if solves == 0 {
            return 50.0;
        }
        let total = self.solve_us_total.load(Ordering::Relaxed);
        total as f64 / solves as f64 / 1000.0
    }

    /// Point-in-time copy of every counter and quantile. Queue/index
    /// figures are passed in by the service, which owns those; `store`
    /// is the pattern store's own counter snapshot and `faults` the
    /// shared retry seam's per-stage telemetry.
    pub fn snapshot(
        &self,
        queue_depth: usize,
        inflight: usize,
        index_records: usize,
        store: StoreStatsSnapshot,
        faults: FaultReport,
    ) -> StatsSnapshot {
        let hit_hist = self.hit_latency.snapshot();
        let miss_hist = self.miss_latency.snapshot();
        let (hit_p50_us, hit_p99_us, hit_max_us) = (
            hit_hist.quantile(0.50),
            hit_hist.quantile(0.99),
            hit_hist.max,
        );
        let (miss_p50_us, miss_p99_us, miss_max_us) = (
            miss_hist.quantile(0.50),
            miss_hist.quantile(0.99),
            miss_hist.max,
        );
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
        StatsSnapshot {
            requests: load(&self.requests),
            hits: load(&self.hits),
            misses: load(&self.misses),
            coalesced: load(&self.coalesced),
            rejected: load(&self.rejected),
            timeouts: load(&self.timeouts),
            degraded: load(&self.degraded),
            solves: load(&self.solves),
            solve_errors: load(&self.solve_errors),
            refreshes_scheduled: load(&self.refreshes_scheduled),
            refreshes_dropped: load(&self.refreshes_dropped),
            refreshes_done: load(&self.refreshes_done),
            avg_solve_ms: self.avg_solve_ms(),
            queue_depth,
            inflight,
            index_records,
            index_hits: store.hits,
            index_misses: store.misses,
            store,
            faults,
            hit_p50_us,
            hit_p99_us,
            hit_max_us,
            miss_p50_us,
            miss_p99_us,
            miss_max_us,
            hit_hist,
            miss_hist,
        }
    }
}

/// What [`ServiceStats::snapshot`] returns — the `stats` endpoint
/// payload.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    pub requests: u64,
    /// Served synchronously from the in-memory index.
    pub hits: u64,
    /// Went through the worker pool and were answered (any rung).
    pub misses: u64,
    /// Attached to an already-in-flight identical solve.
    pub coalesced: u64,
    /// Refused at admission (queue full or draining).
    pub rejected: u64,
    /// Expired deadlines (queued or waiting).
    pub timeouts: u64,
    /// Worker answers below full service.
    pub degraded: u64,
    /// Worker solves completed (foreground + refresh).
    pub solves: u64,
    /// Worker solves that produced no plan at all.
    pub solve_errors: u64,
    pub refreshes_scheduled: u64,
    pub refreshes_dropped: u64,
    pub refreshes_done: u64,
    pub avg_solve_ms: f64,
    pub queue_depth: usize,
    /// Distinct reuse keys currently being solved.
    pub inflight: usize,
    pub index_records: usize,
    /// Key-match hits at the index (a superset of served hits: an
    /// expired record matches the key but is re-searched anyway).
    pub index_hits: u64,
    pub index_misses: u64,
    /// The sharded pattern store's own counters — staleness, appends,
    /// eviction, compaction, crash-recovery tallies.
    pub store: StoreStatsSnapshot,
    /// The shared retry seam's per-stage telemetry (retries, budget
    /// exhaustions, timeouts, panics, virtual backoff seconds). All
    /// zeros when the service runs without a retry policy.
    pub faults: FaultReport,
    pub hit_p50_us: u64,
    pub hit_p99_us: u64,
    pub hit_max_us: u64,
    pub miss_p50_us: u64,
    pub miss_p99_us: u64,
    pub miss_max_us: u64,
    /// Full latency distributions (the quantile fields above are views
    /// of these) — what the Prometheus exposition exports as
    /// `_bucket` series.
    pub hit_hist: HistogramSnapshot,
    pub miss_hist: HistogramSnapshot,
}

impl StatsSnapshot {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("hits", Json::Num(self.hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("coalesced", Json::Num(self.coalesced as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("solves", Json::Num(self.solves as f64)),
            ("solve_errors", Json::Num(self.solve_errors as f64)),
            (
                "refreshes_scheduled",
                Json::Num(self.refreshes_scheduled as f64),
            ),
            (
                "refreshes_dropped",
                Json::Num(self.refreshes_dropped as f64),
            ),
            ("refreshes_done", Json::Num(self.refreshes_done as f64)),
            ("avg_solve_ms", Json::Num(self.avg_solve_ms)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("inflight", Json::Num(self.inflight as f64)),
            ("index_records", Json::Num(self.index_records as f64)),
            ("index_hits", Json::Num(self.index_hits as f64)),
            ("index_misses", Json::Num(self.index_misses as f64)),
            ("hit_p50_us", Json::Num(self.hit_p50_us as f64)),
            ("hit_p99_us", Json::Num(self.hit_p99_us as f64)),
            ("hit_max_us", Json::Num(self.hit_max_us as f64)),
            ("miss_p50_us", Json::Num(self.miss_p50_us as f64)),
            ("miss_p99_us", Json::Num(self.miss_p99_us as f64)),
            ("miss_max_us", Json::Num(self.miss_max_us as f64)),
            ("faults", self.faults.to_json()),
        ];
        fields.extend(self.store.to_json_fields());
        Json::obj(fields)
    }

    /// The Prometheus text exposition the `metrics` op serves: every
    /// counter as a `_total`, live depths as gauges, the per-stage
    /// retry tallies as one labeled family each, and the full latency
    /// distributions as histogram triples.
    pub fn to_prometheus(&self) -> String {
        let mut p = PromText::new();
        p.counter(
            "offload_requests_total",
            "Plan requests admitted (any class).",
            self.requests as f64,
        );
        p.counter(
            "offload_hits_total",
            "Requests served synchronously from the index.",
            self.hits as f64,
        );
        p.counter(
            "offload_misses_total",
            "Requests that went through the worker pool.",
            self.misses as f64,
        );
        p.counter(
            "offload_coalesced_total",
            "Requests attached to an in-flight identical solve.",
            self.coalesced as f64,
        );
        p.counter(
            "offload_rejected_total",
            "Requests refused at admission.",
            self.rejected as f64,
        );
        p.counter(
            "offload_timeouts_total",
            "Requests whose deadline expired.",
            self.timeouts as f64,
        );
        p.counter(
            "offload_degraded_total",
            "Answers below full service level.",
            self.degraded as f64,
        );
        p.counter(
            "offload_solves_total",
            "Worker solves completed (foreground + refresh).",
            self.solves as f64,
        );
        p.counter(
            "offload_solve_errors_total",
            "Worker solves that produced no plan.",
            self.solve_errors as f64,
        );
        p.counter(
            "offload_refreshes_scheduled_total",
            "Refresh-ahead re-searches enqueued.",
            self.refreshes_scheduled as f64,
        );
        p.counter(
            "offload_refreshes_dropped_total",
            "Refresh-ahead re-searches dropped (queue full).",
            self.refreshes_dropped as f64,
        );
        p.counter(
            "offload_refreshes_done_total",
            "Refresh-ahead re-searches completed.",
            self.refreshes_done as f64,
        );
        p.gauge(
            "offload_avg_solve_ms",
            "Mean worker solve time, milliseconds.",
            self.avg_solve_ms,
        );
        p.gauge(
            "offload_queue_depth",
            "Jobs waiting in the admission queue.",
            self.queue_depth as f64,
        );
        p.gauge(
            "offload_inflight",
            "Distinct reuse keys currently being solved.",
            self.inflight as f64,
        );
        p.gauge(
            "offload_index_records",
            "Records in the in-memory hit index.",
            self.index_records as f64,
        );
        p.counter(
            "offload_store_hits_total",
            "Pattern-store key-match lookups.",
            self.store.hits as f64,
        );
        p.counter(
            "offload_store_misses_total",
            "Pattern-store lookup misses.",
            self.store.misses as f64,
        );
        p.counter(
            "offload_store_stale_hits_total",
            "Lookups that matched an expired record.",
            self.store.stale_hits as f64,
        );
        p.counter(
            "offload_store_appends_total",
            "Records appended to the sharded store.",
            self.store.appends as f64,
        );
        p.counter(
            "offload_store_evictions_total",
            "Records evicted over capacity.",
            self.store.evictions as f64,
        );
        p.counter(
            "offload_store_compactions_total",
            "Shard log compactions.",
            self.store.compactions as f64,
        );
        p.counter(
            "offload_store_torn_truncations_total",
            "Torn shard tails truncated at recovery.",
            self.store.torn_truncations as f64,
        );
        p.gauge(
            "offload_store_quarantined_bytes",
            "Bytes quarantined by crash recovery.",
            self.store.quarantined_bytes as f64,
        );
        let stages = |f: &dyn Fn(&crate::search::StageReport) -> f64| {
            [
                ("measure", f(&self.faults.measure)),
                ("verify", f(&self.faults.verify)),
                ("deploy", f(&self.faults.deploy)),
            ]
        };
        p.counter_vec(
            "offload_retries_total",
            "Backend retries beyond the first attempt, by stage.",
            "stage",
            &stages(&|s| s.retries as f64),
        );
        p.counter_vec(
            "offload_retry_exhausted_total",
            "Calls that spent their whole retry budget, by stage.",
            "stage",
            &stages(&|s| s.exhausted as f64),
        );
        p.counter_vec(
            "offload_retry_timeouts_total",
            "Calls that hit the stage deadline, by stage.",
            "stage",
            &stages(&|s| s.timeouts as f64),
        );
        p.counter_vec(
            "offload_retry_panics_total",
            "Backend panics caught, by stage.",
            "stage",
            &stages(&|s| s.panics as f64),
        );
        p.counter_vec(
            "offload_backoff_seconds_total",
            "Virtual backoff seconds waited, by stage.",
            "stage",
            &stages(&|s| s.backoff_s),
        );
        p.histogram(
            "offload_hit_latency_us",
            "Hit-path submit-to-answer latency, microseconds.",
            &self.hit_hist,
        );
        p.histogram(
            "offload_miss_latency_us",
            "Miss-path submit-to-answer latency, microseconds.",
            &self.miss_hist,
        );
        p.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::StageReport;

    #[test]
    fn histogram_quantiles_track_samples() {
        let stats = ServiceStats::new();
        for us in 1..=100u64 {
            stats.hit(us);
        }
        let snap = stats.snapshot(
            0,
            0,
            0,
            StoreStatsSnapshot::default(),
            FaultReport::default(),
        );
        assert_eq!(snap.hit_p50_us, 50);
        assert_eq!(snap.hit_p99_us, 99);
        assert_eq!(snap.hit_max_us, 100);
        assert_eq!(snap.hits, 100);
    }

    #[test]
    fn empty_latencies_report_zero() {
        let snap = ServiceStats::new().snapshot(
            0,
            0,
            0,
            StoreStatsSnapshot::default(),
            FaultReport::default(),
        );
        assert_eq!(
            (snap.hit_p50_us, snap.hit_p99_us, snap.hit_max_us),
            (0, 0, 0)
        );
        assert_eq!(
            (snap.miss_p50_us, snap.miss_p99_us, snap.miss_max_us),
            (0, 0, 0)
        );
    }

    fn sample_faults() -> FaultReport {
        FaultReport {
            measure: StageReport {
                calls: 5,
                retries: 2,
                exhausted: 1,
                timeouts: 0,
                panics: 0,
                backoff_s: 90.0,
            },
            verify: StageReport::default(),
            deploy: StageReport::default(),
        }
    }

    #[test]
    fn snapshot_round_trips_to_json() {
        let stats = ServiceStats::new();
        stats.request();
        stats.hit(5);
        stats.request();
        stats.miss(5000);
        stats.solve(4900, false);
        let store = StoreStatsSnapshot {
            hits: 10,
            misses: 2,
            evictions: 4,
            compactions: 1,
            stale_hits: 3,
            ..StoreStatsSnapshot::default()
        };
        let snap = stats.snapshot(3, 1, 7, store, sample_faults());
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.queue_depth, 3);
        assert_eq!(snap.index_records, 7);
        let j = snap.to_json();
        assert_eq!(j.get(&["hits"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["hit_p50_us"]).unwrap().as_f64(), Some(5.0));
        assert_eq!(
            j.get(&["miss_p99_us"]).unwrap().as_f64(),
            Some(5000.0)
        );
        assert_eq!(j.get(&["index_hits"]).unwrap().as_f64(), Some(10.0));
        // The store's counters ride along in the same flat object —
        // the contract the TCP smoke asserts on.
        assert_eq!(j.get(&["evictions"]).unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get(&["compactions"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["stale_hits"]).unwrap().as_f64(), Some(3.0));
        // The retry telemetry is nested under "faults" — the PR 6
        // counters the service used to drop.
        assert_eq!(
            j.get(&["faults", "total_retries"]).unwrap().as_f64(),
            Some(2.0)
        );
        assert_eq!(
            j.get(&["faults", "measure", "backoff_s"])
                .unwrap()
                .as_f64(),
            Some(90.0)
        );
        // avg solve reflects the one recorded solve.
        assert!((snap.avg_solve_ms - 4.9).abs() < 1e-9);
    }

    /// Golden schema: the exact top-level key set of the `stats` op
    /// payload. Adding a field is fine (add it here); renaming or
    /// dropping one breaks dashboards and the CI smoke, so this test
    /// makes that a deliberate act.
    #[test]
    fn golden_stats_schema() {
        let snap = ServiceStats::new().snapshot(
            0,
            0,
            0,
            StoreStatsSnapshot::default(),
            FaultReport::default(),
        );
        let j = snap.to_json();
        let keys: Vec<&str> =
            j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "appends",
                "avg_solve_ms",
                "coalesced",
                "compactions",
                "degraded",
                "evictions",
                "faults",
                "hit_max_us",
                "hit_p50_us",
                "hit_p99_us",
                "hits",
                "index_hits",
                "index_misses",
                "index_records",
                "inflight",
                "miss_max_us",
                "miss_p50_us",
                "miss_p99_us",
                "misses",
                "quarantined_bytes",
                "queue_depth",
                "refreshes_done",
                "refreshes_dropped",
                "refreshes_scheduled",
                "rejected",
                "requests",
                "solve_errors",
                "solves",
                "stale_hits",
                "stale_writes_dropped",
                "store_hits",
                "store_misses",
                "timeouts",
                "torn_truncations",
            ]
        );
        // Each stage block under "faults" keeps the StageReport shape.
        for stage in ["measure", "verify", "deploy"] {
            let s = j.get(&["faults", stage]).unwrap();
            let keys: Vec<&str> =
                s.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
            assert_eq!(
                keys,
                vec![
                    "backoff_s",
                    "calls",
                    "exhausted",
                    "panics",
                    "retries",
                    "timeouts",
                ]
            );
        }
    }

    #[test]
    fn prometheus_exposition_has_all_families() {
        let stats = ServiceStats::new();
        stats.request();
        stats.hit(5);
        stats.miss(4200);
        let snap = stats.snapshot(
            2,
            1,
            7,
            StoreStatsSnapshot::default(),
            sample_faults(),
        );
        let text = snap.to_prometheus();
        for family in [
            "offload_requests_total",
            "offload_hits_total",
            "offload_misses_total",
            "offload_queue_depth",
            "offload_inflight",
            "offload_store_appends_total",
            "offload_retries_total",
            "offload_backoff_seconds_total",
            "offload_hit_latency_us",
            "offload_miss_latency_us",
        ] {
            assert!(
                text.contains(&format!("# TYPE {family} ")),
                "missing family {family}"
            );
        }
        assert!(text
            .contains("offload_retries_total{stage=\"measure\"} 2\n"));
        assert!(text.contains("offload_hit_latency_us_count 1\n"));
        assert!(text
            .contains("offload_hit_latency_us_bucket{le=\"+Inf\"} 1\n"));
        // Every sample line is "name[{labels}] value" — parseable by
        // anything that reads the exposition format.
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').unwrap();
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "bad sample: {line}");
        }
    }
}
