//! Service telemetry: lock-free counters plus per-class latency rings
//! for p50/p99.
//!
//! Latencies land in a fixed-size ring (most recent [`RING_CAP`]
//! samples per class), so quantiles track *current* behavior under
//! sustained traffic instead of averaging over the process lifetime,
//! and memory stays bounded at any request rate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::store::StoreStatsSnapshot;
use crate::util::json::Json;

/// Samples kept per latency class.
const RING_CAP: usize = 8192;

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<u64>,
    next: usize,
    total: u64,
}

impl Ring {
    fn push(&mut self, us: u64) {
        if self.buf.len() < RING_CAP {
            self.buf.push(us);
        } else {
            self.buf[self.next] = us;
        }
        self.next = (self.next + 1) % RING_CAP;
        self.total += 1;
    }

    fn quantiles(&self) -> (u64, u64, u64) {
        if self.buf.is_empty() {
            return (0, 0, 0);
        }
        let mut sorted = self.buf.clone();
        sorted.sort_unstable();
        (
            percentile(&sorted, 0.50),
            percentile(&sorted, 0.99),
            *sorted.last().unwrap(),
        )
    }
}

/// Nearest-rank percentile over a sorted slice.
fn percentile(sorted: &[u64], q: f64) -> u64 {
    let rank = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Shared, thread-safe service counters. One instance lives in the
/// service; every worker and caller thread updates it directly.
#[derive(Debug, Default)]
pub struct ServiceStats {
    requests: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    rejected: AtomicU64,
    timeouts: AtomicU64,
    degraded: AtomicU64,
    solves: AtomicU64,
    solve_errors: AtomicU64,
    solve_us_total: AtomicU64,
    refreshes_scheduled: AtomicU64,
    refreshes_dropped: AtomicU64,
    refreshes_done: AtomicU64,
    hit_latency: Mutex<Ring>,
    miss_latency: Mutex<Ring>,
}

impl ServiceStats {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn request(&self) {
        Self::bump(&self.requests);
    }

    pub(crate) fn hit(&self, latency_us: u64) {
        Self::bump(&self.hits);
        self.hit_latency
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(latency_us);
    }

    pub(crate) fn miss(&self, latency_us: u64) {
        Self::bump(&self.misses);
        self.miss_latency
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(latency_us);
    }

    pub(crate) fn coalesced(&self) {
        Self::bump(&self.coalesced);
    }

    pub(crate) fn rejected(&self) {
        Self::bump(&self.rejected);
    }

    pub(crate) fn timeout(&self) {
        Self::bump(&self.timeouts);
    }

    pub(crate) fn degraded(&self) {
        Self::bump(&self.degraded);
    }

    pub(crate) fn solve(&self, solve_us: u64, failed: bool) {
        Self::bump(&self.solves);
        self.solve_us_total.fetch_add(solve_us, Ordering::Relaxed);
        if failed {
            Self::bump(&self.solve_errors);
        }
    }

    pub(crate) fn refresh_scheduled(&self) {
        Self::bump(&self.refreshes_scheduled);
    }

    pub(crate) fn refresh_dropped(&self) {
        Self::bump(&self.refreshes_dropped);
    }

    pub(crate) fn refresh_done(&self) {
        Self::bump(&self.refreshes_done);
    }

    /// Mean worker solve time so far, milliseconds (the retry-hint
    /// input). A fallback guess before any solve has completed.
    pub(crate) fn avg_solve_ms(&self) -> f64 {
        let solves = self.solves.load(Ordering::Relaxed);
        if solves == 0 {
            return 50.0;
        }
        let total = self.solve_us_total.load(Ordering::Relaxed);
        total as f64 / solves as f64 / 1000.0
    }

    /// Point-in-time copy of every counter and quantile. Queue/index
    /// figures are passed in by the service, which owns those; `store`
    /// is the pattern store's own counter snapshot (lookups, staleness,
    /// eviction, compaction, recovery).
    pub fn snapshot(
        &self,
        queue_depth: usize,
        inflight: usize,
        index_records: usize,
        store: StoreStatsSnapshot,
    ) -> StatsSnapshot {
        let (hit_p50_us, hit_p99_us, hit_max_us) = self
            .hit_latency
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .quantiles();
        let (miss_p50_us, miss_p99_us, miss_max_us) = self
            .miss_latency
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .quantiles();
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
        StatsSnapshot {
            requests: load(&self.requests),
            hits: load(&self.hits),
            misses: load(&self.misses),
            coalesced: load(&self.coalesced),
            rejected: load(&self.rejected),
            timeouts: load(&self.timeouts),
            degraded: load(&self.degraded),
            solves: load(&self.solves),
            solve_errors: load(&self.solve_errors),
            refreshes_scheduled: load(&self.refreshes_scheduled),
            refreshes_dropped: load(&self.refreshes_dropped),
            refreshes_done: load(&self.refreshes_done),
            avg_solve_ms: self.avg_solve_ms(),
            queue_depth,
            inflight,
            index_records,
            index_hits: store.hits,
            index_misses: store.misses,
            store,
            hit_p50_us,
            hit_p99_us,
            hit_max_us,
            miss_p50_us,
            miss_p99_us,
            miss_max_us,
        }
    }
}

/// What [`ServiceStats::snapshot`] returns — the `stats` endpoint
/// payload.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    pub requests: u64,
    /// Served synchronously from the in-memory index.
    pub hits: u64,
    /// Went through the worker pool and were answered (any rung).
    pub misses: u64,
    /// Attached to an already-in-flight identical solve.
    pub coalesced: u64,
    /// Refused at admission (queue full or draining).
    pub rejected: u64,
    /// Expired deadlines (queued or waiting).
    pub timeouts: u64,
    /// Worker answers below full service.
    pub degraded: u64,
    /// Worker solves completed (foreground + refresh).
    pub solves: u64,
    /// Worker solves that produced no plan at all.
    pub solve_errors: u64,
    pub refreshes_scheduled: u64,
    pub refreshes_dropped: u64,
    pub refreshes_done: u64,
    pub avg_solve_ms: f64,
    pub queue_depth: usize,
    /// Distinct reuse keys currently being solved.
    pub inflight: usize,
    pub index_records: usize,
    /// Key-match hits at the index (a superset of served hits: an
    /// expired record matches the key but is re-searched anyway).
    pub index_hits: u64,
    pub index_misses: u64,
    /// The sharded pattern store's own counters — staleness, appends,
    /// eviction, compaction, crash-recovery tallies.
    pub store: StoreStatsSnapshot,
    pub hit_p50_us: u64,
    pub hit_p99_us: u64,
    pub hit_max_us: u64,
    pub miss_p50_us: u64,
    pub miss_p99_us: u64,
    pub miss_max_us: u64,
}

impl StatsSnapshot {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("hits", Json::Num(self.hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("coalesced", Json::Num(self.coalesced as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("solves", Json::Num(self.solves as f64)),
            ("solve_errors", Json::Num(self.solve_errors as f64)),
            (
                "refreshes_scheduled",
                Json::Num(self.refreshes_scheduled as f64),
            ),
            (
                "refreshes_dropped",
                Json::Num(self.refreshes_dropped as f64),
            ),
            ("refreshes_done", Json::Num(self.refreshes_done as f64)),
            ("avg_solve_ms", Json::Num(self.avg_solve_ms)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("inflight", Json::Num(self.inflight as f64)),
            ("index_records", Json::Num(self.index_records as f64)),
            ("index_hits", Json::Num(self.index_hits as f64)),
            ("index_misses", Json::Num(self.index_misses as f64)),
            ("hit_p50_us", Json::Num(self.hit_p50_us as f64)),
            ("hit_p99_us", Json::Num(self.hit_p99_us as f64)),
            ("hit_max_us", Json::Num(self.hit_max_us as f64)),
            ("miss_p50_us", Json::Num(self.miss_p50_us as f64)),
            ("miss_p99_us", Json::Num(self.miss_p99_us as f64)),
            ("miss_max_us", Json::Num(self.miss_max_us as f64)),
        ];
        fields.extend(self.store.to_json_fields());
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_quantiles_track_recent_samples() {
        let mut r = Ring::default();
        for us in 1..=100u64 {
            r.push(us);
        }
        let (p50, p99, max) = r.quantiles();
        assert_eq!(p50, 50);
        assert_eq!(p99, 99);
        assert_eq!(max, 100);
    }

    #[test]
    fn ring_wraps_at_capacity() {
        let mut r = Ring::default();
        for _ in 0..RING_CAP {
            r.push(1);
        }
        // A full ring of 1s, then overwrite everything with 1000s.
        for _ in 0..RING_CAP {
            r.push(1000);
        }
        let (p50, p99, _) = r.quantiles();
        assert_eq!(p50, 1000);
        assert_eq!(p99, 1000);
        assert_eq!(r.total, 2 * RING_CAP as u64);
        assert_eq!(r.buf.len(), RING_CAP);
    }

    #[test]
    fn empty_ring_reports_zero() {
        assert_eq!(Ring::default().quantiles(), (0, 0, 0));
    }

    #[test]
    fn snapshot_round_trips_to_json() {
        let stats = ServiceStats::new();
        stats.request();
        stats.hit(5);
        stats.request();
        stats.miss(5000);
        stats.solve(4900, false);
        let store = StoreStatsSnapshot {
            hits: 10,
            misses: 2,
            evictions: 4,
            compactions: 1,
            stale_hits: 3,
            ..StoreStatsSnapshot::default()
        };
        let snap = stats.snapshot(3, 1, 7, store);
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.queue_depth, 3);
        assert_eq!(snap.index_records, 7);
        let j = snap.to_json();
        assert_eq!(j.get(&["hits"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["hit_p50_us"]).unwrap().as_f64(), Some(5.0));
        assert_eq!(
            j.get(&["miss_p99_us"]).unwrap().as_f64(),
            Some(5000.0)
        );
        assert_eq!(j.get(&["index_hits"]).unwrap().as_f64(), Some(10.0));
        // The store's counters ride along in the same flat object —
        // the contract the TCP smoke asserts on.
        assert_eq!(j.get(&["evictions"]).unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get(&["compactions"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["stale_hits"]).unwrap().as_f64(), Some(3.0));
        // avg solve reflects the one recorded solve.
        assert!((snap.avg_solve_ms - 4.9).abs() < 1e-9);
    }
}
