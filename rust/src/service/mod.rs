//! Offload-as-a-service: a resident daemon serving plan requests at
//! traffic scale.
//!
//! Every solve in this crate used to be a one-shot CLI process — parse
//! the app, run the funnel, write the pattern DB, exit. That shape
//! cannot serve the paper's environment-adaptive vision, where plan
//! requests arrive continuously from a fleet. This module keeps the
//! whole machine resident: a [`Service`] owns a shared in-memory
//! [`crate::envadapt::PatternIndex`], a bounded admission queue, and a
//! worker pool built over the existing
//! [`crate::envadapt::Batch`]/[`crate::envadapt::Pipeline`] machinery.
//!
//! Two service classes keep a flood of cold solves from ever starving
//! cached lookups:
//!
//! * **Hits** — a request whose full [`crate::envadapt::ReuseKey`]
//!   matches an indexed record is answered *synchronously on the caller
//!   thread* from memory, in microseconds. Hits never enter the queue,
//!   so no amount of cold-solve backlog can delay them.
//! * **Misses** — occupy a queue slot and a worker. Duplicate in-flight
//!   keys coalesce into one solve (every waiter gets the same plan),
//!   per-request deadlines are honored (expired work is dropped with a
//!   typed timeout, never a hang), and failures degrade through the
//!   [`crate::envadapt::ServiceLevel`] ladder instead of erroring.
//!
//! Admission control is explicit: when the queue is full the request is
//! rejected *immediately* with a typed
//! [`crate::search::OffloadError`] (`stage=queue`, `class=transient`)
//! and a `retry_after_ms` hint derived from the backlog — callers see
//! backpressure, not latency.
//!
//! The refresh-ahead policy closes the expiry gap: a hit whose age
//! exceeds a configurable fraction (default 80%) of `max_age` is served
//! immediately *and* a background re-search is enqueued, so a hot key
//! never waits on a cold solve just because its record aged out.
//!
//! Submodules: [`queue`] (bounded MPMC admission queue), [`server`]
//! (the `Service`, worker pool, coalescing), [`stats`] (counters +
//! latency quantiles), [`protocol`] (newline-delimited-JSON wire format
//! over TCP, plus the client used by `repro client`).
//!
//! ```
//! use fpga_offload::service::{PlanRequest, Service, ServiceConfig};
//! use fpga_offload::util::tempdir::TempDir;
//!
//! let dir = TempDir::new("svc-doc").unwrap();
//! let mut cfg = ServiceConfig::default();
//! cfg.pattern_db = Some(dir.path().to_path_buf());
//! cfg.workers = 1;
//! let svc = Service::start(cfg).unwrap();
//! let src = "
//! #define N 256
//! float a[N]; float out[N];
//! int main() {
//!     for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
//!     for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
//!     return 0;
//! }";
//! let cold = svc.request(PlanRequest::new("demo", src));
//! assert!(cold.result.is_ok());
//! let warm = svc.request(PlanRequest::new("demo", src));
//! assert!(warm.is_hit());
//! svc.shutdown();
//! ```

pub mod protocol;
pub mod queue;
pub mod server;
pub mod stats;

use std::path::PathBuf;
use std::time::Duration;

use crate::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use crate::envadapt::ServiceLevel;
use crate::gpu::TESLA_T4;
use crate::hls::ARRIA10_GX;
use crate::obs::TraceConfig;
use crate::search::{
    Backend, CpuBaseline, FpgaBackend, GpuBackend, OffloadError,
    OmpBackend, RetryPolicy, SearchConfig,
};

pub use protocol::{Client, TcpServer, DEFAULT_ADDR};
pub use queue::{BoundedQueue, PushError};
pub use server::Service;
pub use stats::{ServiceStats, StatsSnapshot};

/// Which bundled destination backend a service solves misses on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Fpga,
    Gpu,
    Omp,
    Cpu,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fpga" => Some(BackendKind::Fpga),
            "gpu" => Some(BackendKind::Gpu),
            "omp" => Some(BackendKind::Omp),
            "cpu" => Some(BackendKind::Cpu),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Fpga => "fpga",
            BackendKind::Gpu => "gpu",
            BackendKind::Omp => "omp",
            BackendKind::Cpu => "cpu",
        }
    }

    /// Construct the bundled backend for this destination (the same
    /// device statics the CLI uses).
    pub fn build(self) -> Box<dyn Backend + Send + Sync> {
        match self {
            BackendKind::Fpga => Box::new(FpgaBackend {
                cpu: &XEON_BRONZE_3104,
                device: &ARRIA10_GX,
            }),
            BackendKind::Gpu => Box::new(GpuBackend {
                cpu: &XEON_BRONZE_3104,
                gpu: &TESLA_T4,
                device: &ARRIA10_GX,
            }),
            BackendKind::Omp => Box::new(OmpBackend {
                cpu: &XEON_BRONZE_3104,
                omp: &XEON_GOLD_6130,
                device: &ARRIA10_GX,
            }),
            BackendKind::Cpu => Box::new(CpuBaseline {
                cpu: &XEON_BRONZE_3104,
                device: &ARRIA10_GX,
            }),
        }
    }
}

/// Everything a [`Service`] is configured with.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Funnel configuration shared by every miss solve.
    pub search: SearchConfig,
    /// Destination backend misses are solved on.
    pub backend: BackendKind,
    /// Pattern-DB directory. `Some` enables the in-memory hit index and
    /// write-through persistence; `None` means every request is a cold
    /// solve and nothing survives the process.
    pub pattern_db: Option<PathBuf>,
    /// Worker threads solving misses. `0` is allowed — nothing drains
    /// the queue (admission-control tests use this to fill it
    /// deterministically).
    pub workers: usize,
    /// Queue capacity; the `workers+queue_cap+1`-th concurrent distinct
    /// miss is rejected with a typed admission error.
    pub queue_cap: usize,
    /// Age policy for the hit path, mirroring
    /// [`crate::envadapt::Pipeline::with_max_age`]: an indexed record
    /// older than this is a miss (re-searched), and unstamped records
    /// count as infinitely old. `None` serves hits forever.
    pub max_age: Option<Duration>,
    /// Refresh-ahead fraction of `max_age` (default 0.8): a hit older
    /// than `refresh_ahead * max_age` but younger than `max_age` is
    /// served immediately *and* a background re-search is enqueued
    /// (dropped silently if the queue is full — refresh is best
    /// effort). Only meaningful with `max_age` set.
    pub refresh_ahead: f64,
    /// Retry/backoff budget wrapped around every worker solve (the
    /// PR 6 seam). Per-request deadlines tighten this policy's
    /// `stage_deadline_s`, so a hung simulated build trips the request
    /// deadline too.
    pub retry: Option<RetryPolicy>,
    /// Live-record cap on the pattern store. Over capacity, the
    /// cheapest-to-recompute records (low solve investment, high
    /// staleness — see [`crate::store::evict`]) are evicted on the next
    /// write. `None` (the default) never evicts.
    pub db_capacity: Option<usize>,
    /// End-to-end tracing knobs (span ring capacity, head sampling).
    /// Tracing is on by default; `repro serve --no-trace` turns it off,
    /// at which point every span site is a no-op.
    pub trace: TraceConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            search: SearchConfig::default(),
            backend: BackendKind::Fpga,
            pattern_db: None,
            workers: 2,
            queue_cap: 64,
            max_age: None,
            refresh_ahead: 0.8,
            retry: None,
            db_capacity: None,
            trace: TraceConfig::default(),
        }
    }
}

impl ServiceConfig {
    pub fn validate(&self) -> Result<(), String> {
        self.search.validate()?;
        if self.queue_cap == 0 {
            return Err("queue_cap must be >= 1".into());
        }
        if !(self.refresh_ahead > 0.0 && self.refresh_ahead <= 1.0) {
            return Err(format!(
                "refresh_ahead must be in (0, 1], got {}",
                self.refresh_ahead
            ));
        }
        if let Some(policy) = &self.retry {
            policy.validate()?;
        }
        if self.db_capacity == Some(0) {
            return Err(
                "db_capacity must be >= 1 (omit it to disable eviction)"
                    .into(),
            );
        }
        self.trace.validate()?;
        Ok(())
    }
}

/// One plan request as the service sees it, whatever front it arrived
/// through (in-process call, TCP line, CLI client).
#[derive(Debug, Clone)]
pub struct PlanRequest {
    pub app: String,
    pub source: String,
    /// Entry function for profiling and verification.
    pub entry: String,
    pub seed: u64,
    /// Run the function-block detection/confirmation path.
    pub func_blocks: bool,
    /// Wall-clock budget from admission, milliseconds. An expired
    /// request is answered with a typed timeout
    /// (`stage=queue, class=timeout`) — never left hanging, never
    /// solved past its deadline's usefulness.
    pub deadline_ms: Option<u64>,
}

impl PlanRequest {
    pub fn new(app: impl Into<String>, source: impl Into<String>) -> Self {
        PlanRequest {
            app: app.into(),
            source: source.into(),
            entry: "main".into(),
            seed: 42,
            func_blocks: false,
            deadline_ms: None,
        }
    }
}

/// Which service class answered a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeClass {
    /// Answered synchronously from the in-memory index.
    Hit,
    /// Went through the queue and a worker solve (or was rejected /
    /// timed out trying).
    Miss,
}

impl ServeClass {
    pub fn as_str(self) -> &'static str {
        match self {
            ServeClass::Hit => "hit",
            ServeClass::Miss => "miss",
        }
    }
}

/// The plan summary a request is answered with.
#[derive(Debug, Clone)]
pub struct ServedPlan {
    /// Offloaded loop ids of the selected pattern.
    pub best_pattern: Vec<u32>,
    /// Human label ("L12+L13", or "all-CPU").
    pub label: String,
    pub speedup: f64,
    /// Function-block replacements carried by the plan.
    pub blocks: u64,
    /// Whether the plan came from the pattern DB rather than a fresh
    /// funnel run.
    pub cached: bool,
    /// Whether the plan's verification outcome holds up (see
    /// [`crate::envadapt::Plan::verified_ok`]).
    pub verified_ok: bool,
    /// Ladder rung that served the request ([`ServiceLevel::Full`] for
    /// hits and clean solves).
    pub service: ServiceLevel,
    /// The hit was inside the refresh-ahead window and a background
    /// re-search was scheduled.
    pub refresh_ahead: bool,
}

/// What a [`Service`] answers every request with.
#[derive(Debug, Clone)]
pub struct PlanResponse {
    pub app: String,
    pub class: ServeClass,
    /// The plan, or the typed fault: admission rejects are
    /// `stage=queue, class=transient`; expired deadlines are
    /// `stage=queue, class=timeout`; solve failures keep their pipeline
    /// stage and class.
    pub result: Result<ServedPlan, OffloadError>,
    /// Backpressure hint, set only on admission rejects: how long the
    /// backlog suggests waiting before retrying.
    pub retry_after_ms: Option<u64>,
    /// Submit-to-answer wall time, microseconds.
    pub latency_us: u64,
}

impl PlanResponse {
    /// Whether a plan was served (any ladder rung).
    pub fn ok(&self) -> bool {
        self.result.is_ok()
    }

    pub fn is_hit(&self) -> bool {
        self.class == ServeClass::Hit && self.result.is_ok()
    }

    /// Whether this is a typed admission reject (queue full or service
    /// draining).
    pub fn is_rejected(&self) -> bool {
        matches!(
            &self.result,
            Err(e) if e.stage == crate::search::Stage::Queue
                && e.class == crate::search::FaultClass::Transient
        )
    }

    /// Whether this is a typed deadline expiry.
    pub fn is_timeout(&self) -> bool {
        matches!(
            &self.result,
            Err(e) if e.class == crate::search::FaultClass::Timeout
        )
    }
}
