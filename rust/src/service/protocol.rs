//! The wire format: newline-delimited JSON over TCP, one object per
//! line, one response line per request line.
//!
//! Deliberately thin — the service's whole brain lives in
//! [`Service`](crate::service::Service); this layer only parses lines,
//! maps them to [`PlanRequest`]s, and serializes [`PlanResponse`]s
//! back. Any client that can write a JSON line to a socket can use the
//! daemon; no framing, no state, no protocol negotiation.
//!
//! Request lines:
//!
//! ```json
//! {"id": 1, "op": "plan", "app": "tdfir", "source": "...", "deadline_ms": 5000}
//! {"id": 2, "op": "stats"}
//! {"id": 3, "op": "metrics"}
//! {"id": 4, "op": "trace", "last": 8}
//! {"id": 5, "op": "trace", "trace_id": 42}
//! {"id": 6, "op": "trace", "slow_ms": 50}
//! {"id": 7, "op": "ping"}
//! {"id": 8, "op": "shutdown"}
//! ```
//!
//! `op` defaults to `"plan"`. A plan request without `source` falls
//! back to the bundled workload of that name (and its registered entry
//! point), so `{"app": "tdfir"}` alone is a valid request. Responses
//! echo `id` and `op` and carry `status`: `"ok"`, `"rejected"` (typed
//! admission reject — `retry_after_ms` is set), `"timeout"` (deadline
//! expired), or `"error"`. Malformed lines get a `status:"error"`
//! response and the connection stays up.
//!
//! `metrics` answers with the Prometheus text exposition of the
//! [`StatsSnapshot`](super::StatsSnapshot) in a `"metrics"` string
//! field (the transport stays one JSON line per response; a scraper
//! unwraps the field). `trace` answers with the retained spans as a
//! `"spans"` array — the whole buffer filtered to one trace
//! (`trace_id`), to traces whose root span took at least `slow_ms`
//! (outlier capture), or to the `last` N traces (default 8; ids are
//! minted in order, so the highest ids are the newest).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::envadapt::TestDb;
use crate::obs::SpanRecord;
use crate::search::FaultClass;
use crate::util::json::Json;
use crate::workloads;

use super::server::Service;
use super::{PlanRequest, PlanResponse};

/// Where `repro serve` listens when no `--addr` is given.
pub const DEFAULT_ADDR: &str = "127.0.0.1:7411";

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn str_of(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

/// Build the [`PlanRequest`] a request line describes. Missing `source`
/// resolves against the bundled workloads; missing `entry` against the
/// test-case DB.
fn plan_request_of(line: &Json) -> Result<PlanRequest, String> {
    let app = match line.get(&["app"]).and_then(Json::as_str) {
        Some(a) if !a.is_empty() => a.to_string(),
        _ => return Err("missing \"app\"".into()),
    };
    let source = match line.get(&["source"]).and_then(Json::as_str) {
        Some(src) => src.to_string(),
        None => match workloads::source(&app) {
            Some(src) => src.to_string(),
            None => {
                return Err(format!(
                    "no \"source\" given and \"{app}\" is not a bundled \
                     workload"
                ))
            }
        },
    };
    let mut req = PlanRequest::new(app.clone(), source);
    match line.get(&["entry"]).and_then(Json::as_str) {
        Some(e) => req.entry = e.to_string(),
        None => {
            if let Some(case) = TestDb::builtin().get(&app) {
                req.entry = case.entry.clone();
            }
        }
    }
    if let Some(seed) = line.get(&["seed"]).and_then(Json::as_f64) {
        req.seed = seed as u64;
    }
    if let Some(fb) = line.get(&["func_blocks"]).and_then(Json::as_bool) {
        req.func_blocks = fb;
    }
    if let Some(ms) = line.get(&["deadline_ms"]).and_then(Json::as_f64) {
        req.deadline_ms = Some(ms as u64);
    }
    Ok(req)
}

/// Serialize one service answer as a response line.
fn plan_response_json(id: Option<Json>, resp: &PlanResponse) -> Json {
    let status = match &resp.result {
        Ok(_) => "ok",
        Err(_) if resp.is_rejected() => "rejected",
        Err(e) if e.class == FaultClass::Timeout => "timeout",
        Err(_) => "error",
    };
    let mut fields = vec![
        ("id", id.unwrap_or(Json::Null)),
        ("op", str_of("plan")),
        ("app", str_of(resp.app.clone())),
        ("status", str_of(status)),
        ("class", str_of(resp.class.as_str())),
        ("latency_us", num(resp.latency_us)),
    ];
    match &resp.result {
        Ok(plan) => {
            fields.push((
                "best_pattern",
                Json::Arr(
                    plan.best_pattern
                        .iter()
                        .map(|l| num(u64::from(*l)))
                        .collect(),
                ),
            ));
            fields.push(("label", str_of(plan.label.clone())));
            fields.push(("speedup", Json::Num(plan.speedup)));
            fields.push(("blocks", num(plan.blocks)));
            fields.push(("cached", Json::Bool(plan.cached)));
            fields.push(("verified_ok", Json::Bool(plan.verified_ok)));
            fields.push(("service", str_of(plan.service.as_str())));
            fields
                .push(("refresh_ahead", Json::Bool(plan.refresh_ahead)));
        }
        Err(e) => {
            fields.push(("stage", str_of(e.stage.as_str())));
            fields.push(("fault_class", str_of(e.class.as_str())));
            fields.push(("message", str_of(e.message.clone())));
            fields.push(("attempts", num(u64::from(e.attempts))));
            if let Some(ms) = resp.retry_after_ms {
                fields.push(("retry_after_ms", num(ms)));
            }
        }
    }
    Json::obj(fields)
}

/// Which retained spans a `trace` op answers with: one trace by id,
/// traces whose *root* span took at least `slow_ms`, or the `last` N
/// traces (trace ids are minted in order, so highest = newest). Spans
/// whose root was already evicted out of the ring still match the
/// `last` filter — a truncated trace beats a silently missing one.
fn select_spans(
    spans: Vec<SpanRecord>,
    trace_id: Option<u64>,
    slow_ms: Option<f64>,
    last: usize,
) -> Vec<SpanRecord> {
    use std::collections::BTreeSet;
    if let Some(id) = trace_id {
        return spans.into_iter().filter(|s| s.trace_id == id).collect();
    }
    let keep: BTreeSet<u64> = match slow_ms {
        Some(ms) => {
            let cut_us = (ms * 1000.0).max(0.0) as u64;
            spans
                .iter()
                .filter(|s| s.parent_id == 0 && s.duration_us() >= cut_us)
                .map(|s| s.trace_id)
                .collect()
        }
        None => {
            let mut ids: Vec<u64> =
                spans.iter().map(|s| s.trace_id).collect();
            ids.sort_unstable();
            ids.dedup();
            ids.into_iter().rev().take(last).collect()
        }
    };
    spans
        .into_iter()
        .filter(|s| keep.contains(&s.trace_id))
        .collect()
}

fn error_line(id: Option<Json>, op: &str, message: String) -> Json {
    Json::obj(vec![
        ("id", id.unwrap_or(Json::Null)),
        ("op", str_of(op)),
        ("status", str_of("error")),
        ("message", str_of(message)),
    ])
}

/// Answer one request line. `stop` is raised by a `shutdown` op; the
/// response is still written first so the client sees an ack.
fn handle_line(service: &Service, raw: &str, stop: &AtomicBool) -> Json {
    let line = match Json::parse(raw) {
        Ok(v) => v,
        Err(e) => {
            return error_line(None, "?", format!("malformed line: {e}"))
        }
    };
    let id = line.get(&["id"]).cloned();
    let op = line
        .get(&["op"])
        .and_then(Json::as_str)
        .unwrap_or("plan")
        .to_string();
    match op.as_str() {
        "plan" => match plan_request_of(&line) {
            Ok(req) => plan_response_json(id, &service.request(req)),
            Err(msg) => error_line(id, "plan", msg),
        },
        "stats" => Json::obj(vec![
            ("id", id.unwrap_or(Json::Null)),
            ("op", str_of("stats")),
            ("status", str_of("ok")),
            ("stats", service.stats().to_json()),
        ]),
        "metrics" => Json::obj(vec![
            ("id", id.unwrap_or(Json::Null)),
            ("op", str_of("metrics")),
            ("status", str_of("ok")),
            ("metrics", str_of(service.stats().to_prometheus())),
        ]),
        "trace" => {
            let tid = line
                .get(&["trace_id"])
                .and_then(Json::as_f64)
                .map(|v| v as u64);
            let slow_ms = line.get(&["slow_ms"]).and_then(Json::as_f64);
            let last = line
                .get(&["last"])
                .and_then(Json::as_usize)
                .unwrap_or(8);
            let spans = select_spans(service.spans(), tid, slow_ms, last);
            Json::obj(vec![
                ("id", id.unwrap_or(Json::Null)),
                ("op", str_of("trace")),
                ("status", str_of("ok")),
                (
                    "spans",
                    Json::Arr(
                        spans.iter().map(SpanRecord::to_json).collect(),
                    ),
                ),
            ])
        }
        "ping" => Json::obj(vec![
            ("id", id.unwrap_or(Json::Null)),
            ("op", str_of("ping")),
            ("status", str_of("ok")),
        ]),
        "shutdown" => {
            stop.store(true, Ordering::SeqCst);
            Json::obj(vec![
                ("id", id.unwrap_or(Json::Null)),
                ("op", str_of("shutdown")),
                ("status", str_of("ok")),
            ])
        }
        other => {
            error_line(id, other, format!("unknown op \"{other}\""))
        }
    }
}

fn serve_connection(
    service: &Service,
    stream: TcpStream,
    stop: &AtomicBool,
    local: std::net::SocketAddr,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for raw in reader.lines() {
        let Ok(raw) = raw else { break };
        if raw.trim().is_empty() {
            continue;
        }
        let resp = handle_line(service, &raw, stop);
        if writeln!(writer, "{resp}").is_err() {
            break;
        }
        let _ = writer.flush();
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    if stop.load(Ordering::SeqCst) {
        // A shutdown op arrived on this connection: the accept loop is
        // blocked in accept(), so nudge it awake to see the flag.
        let _ = TcpStream::connect(local);
    }
}

/// The accept loop around a [`Service`]: binds, spawns one detached
/// thread per connection, and drains the service when a `shutdown` op
/// (or [`TcpServer::stop`]) arrives.
pub struct TcpServer {
    service: Arc<Service>,
    local: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (use port `0` for an OS-assigned port — read it back
    /// with [`TcpServer::local_addr`]) and start accepting.
    pub fn bind(service: Service, addr: &str) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("local addr")?;
        let service = Arc::new(service);
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let service = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("offload-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = conn else { continue };
                        let service = Arc::clone(&service);
                        let stop = Arc::clone(&stop);
                        let _ = std::thread::Builder::new()
                            .name("offload-conn".into())
                            .spawn(move || {
                                serve_connection(
                                    &service, stream, &stop, local,
                                )
                            });
                    }
                    service.shutdown();
                })
                .map_err(|e| anyhow::anyhow!("spawning accept: {e}"))?
        };
        Ok(TcpServer {
            service,
            local,
            stop,
            accept: Some(accept),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local
    }

    pub fn service(&self) -> &Service {
        &self.service
    }

    /// Raise the stop flag and nudge the accept loop awake. Safe to
    /// call more than once.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // The listener blocks in accept(); a throwaway connection
        // unblocks it so the flag is seen.
        let _ = TcpStream::connect(self.local);
    }

    /// Block until the accept loop exits (a `shutdown` op arrived, or
    /// [`TcpServer::stop`] was called) and the service has drained.
    pub fn wait(mut self) {
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop();
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

/// A blocking line-protocol client (what `repro client` wraps).
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        let reader =
            BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client {
            writer: stream,
            reader,
        })
    }

    /// Send one request object, block for its response line.
    pub fn roundtrip(&mut self, request: &Json) -> Result<Json> {
        writeln!(self.writer, "{request}").context("writing request")?;
        self.writer.flush().context("flushing request")?;
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .context("reading response")?;
        if n == 0 {
            anyhow::bail!("server closed the connection");
        }
        Json::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("bad response line: {e}"))
    }

    /// Convenience: a full plan request for `app`.
    pub fn plan(
        &mut self,
        id: u64,
        app: &str,
        source: Option<&str>,
        deadline_ms: Option<u64>,
    ) -> Result<Json> {
        let mut fields = vec![
            ("id", num(id)),
            ("op", str_of("plan")),
            ("app", str_of(app)),
        ];
        if let Some(src) = source {
            fields.push(("source", str_of(src)));
        }
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", num(ms)));
        }
        self.roundtrip(&Json::obj(fields))
    }

    pub fn stats(&mut self, id: u64) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![
            ("id", num(id)),
            ("op", str_of("stats")),
        ]))
    }

    /// Fetch the Prometheus text exposition. Returns the unwrapped
    /// text, ready to print or serve to a scraper.
    pub fn metrics(&mut self, id: u64) -> Result<String> {
        let resp = self.roundtrip(&Json::obj(vec![
            ("id", num(id)),
            ("op", str_of("metrics")),
        ]))?;
        match resp.get(&["metrics"]).and_then(Json::as_str) {
            Some(text) => Ok(text.to_string()),
            None => anyhow::bail!("metrics response missing text: {resp}"),
        }
    }

    /// Fetch retained spans: one trace (`trace_id`), slow-root traces
    /// (`slow_ms`), or the last `last` traces — the same filters the
    /// `trace` op takes. Returns the raw response; pull `spans` out
    /// with [`crate::obs::SpanRow::from_json`].
    pub fn trace(
        &mut self,
        id: u64,
        trace_id: Option<u64>,
        slow_ms: Option<f64>,
        last: Option<usize>,
    ) -> Result<Json> {
        let mut fields =
            vec![("id", num(id)), ("op", str_of("trace"))];
        if let Some(t) = trace_id {
            fields.push(("trace_id", num(t)));
        }
        if let Some(ms) = slow_ms {
            fields.push(("slow_ms", Json::Num(ms)));
        }
        if let Some(n) = last {
            fields.push(("last", num(n as u64)));
        }
        self.roundtrip(&Json::obj(fields))
    }

    pub fn ping(&mut self, id: u64) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![
            ("id", num(id)),
            ("op", str_of("ping")),
        ]))
    }

    pub fn shutdown(&mut self, id: u64) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![
            ("id", num(id)),
            ("op", str_of("shutdown")),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envadapt::ServiceLevel;
    use crate::search::{FaultClass, OffloadError, Stage};
    use crate::service::{PlanResponse, ServeClass, ServedPlan};

    fn served() -> PlanResponse {
        PlanResponse {
            app: "demo".into(),
            class: ServeClass::Hit,
            result: Ok(ServedPlan {
                best_pattern: vec![2, 3],
                label: "L2+L3".into(),
                speedup: 4.0,
                blocks: 0,
                cached: true,
                verified_ok: true,
                service: ServiceLevel::Full,
                refresh_ahead: false,
            }),
            retry_after_ms: None,
            latency_us: 12,
        }
    }

    #[test]
    fn plan_response_serializes_ok() {
        let j = plan_response_json(Some(Json::Num(7.0)), &served());
        assert_eq!(j.get(&["status"]).and_then(Json::as_str), Some("ok"));
        assert_eq!(j.get(&["class"]).and_then(Json::as_str), Some("hit"));
        assert_eq!(j.get(&["id"]).and_then(Json::as_f64), Some(7.0));
        assert_eq!(
            j.get(&["speedup"]).and_then(Json::as_f64),
            Some(4.0)
        );
        let loops = j.get(&["best_pattern"]).and_then(Json::as_arr);
        assert_eq!(loops.map(|a| a.len()), Some(2));
    }

    #[test]
    fn reject_and_timeout_get_distinct_statuses() {
        let mut resp = served();
        resp.class = ServeClass::Miss;
        resp.result = Err(OffloadError::new(
            Stage::Queue,
            FaultClass::Transient,
            "queue full",
        ));
        resp.retry_after_ms = Some(120);
        let j = plan_response_json(None, &resp);
        assert_eq!(
            j.get(&["status"]).and_then(Json::as_str),
            Some("rejected")
        );
        assert_eq!(
            j.get(&["retry_after_ms"]).and_then(Json::as_f64),
            Some(120.0)
        );
        resp.result = Err(OffloadError::new(
            Stage::Queue,
            FaultClass::Timeout,
            "deadline expired",
        ));
        resp.retry_after_ms = None;
        let j = plan_response_json(None, &resp);
        assert_eq!(
            j.get(&["status"]).and_then(Json::as_str),
            Some("timeout")
        );
    }

    #[test]
    fn plan_request_resolves_bundled_workloads() {
        let line = Json::parse(r#"{"app": "tdfir"}"#).unwrap();
        let req = plan_request_of(&line).unwrap();
        assert_eq!(req.app, "tdfir");
        assert!(!req.source.is_empty());
        // Entry comes from the registered test case, not the default.
        assert_eq!(
            req.entry,
            TestDb::builtin().get("tdfir").unwrap().entry
        );
        let bad = Json::parse(r#"{"app": "nosuch"}"#).unwrap();
        assert!(plan_request_of(&bad).is_err());
    }

    #[test]
    fn malformed_lines_answer_with_error_status() {
        let j = error_line(None, "?", "malformed line: x".into());
        assert_eq!(
            j.get(&["status"]).and_then(Json::as_str),
            Some("error")
        );
    }

    fn rec(
        trace: u64,
        span: u64,
        parent: u64,
        start: u64,
        end: u64,
    ) -> SpanRecord {
        SpanRecord {
            trace_id: trace,
            span_id: span,
            parent_id: parent,
            name: "request",
            detail: String::new(),
            start_us: start,
            end_us: end,
        }
    }

    #[test]
    fn trace_selection_filters_by_id_slowness_and_recency() {
        let all = vec![
            rec(1, 1, 0, 0, 900_000),
            rec(1, 2, 1, 10, 50),
            rec(2, 1, 0, 0, 1_000),
            rec(3, 1, 0, 0, 60_000),
        ];
        let one = select_spans(all.clone(), Some(1), None, 8);
        assert_eq!(one.len(), 2);
        assert!(one.iter().all(|s| s.trace_id == 1));
        // slow_ms keys off the root span's duration.
        let slow = select_spans(all.clone(), None, Some(50.0), 8);
        let ids: Vec<u64> = slow.iter().map(|s| s.trace_id).collect();
        assert!(ids.contains(&1) && ids.contains(&3) && !ids.contains(&2));
        // last N keeps the newest trace ids.
        let last = select_spans(all, None, None, 2);
        assert!(last.iter().all(|s| s.trace_id >= 2));
        assert_eq!(last.len(), 2);
    }
}
