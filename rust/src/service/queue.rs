//! Bounded MPMC admission queue: `Mutex<VecDeque>` + `Condvar`, no
//! external dependencies.
//!
//! The shape is deliberate: **pushes never block**. A full queue is an
//! admission decision the caller must see *immediately* (so the service
//! can answer with a typed reject + retry hint), not a hidden stall.
//! Pops block — that side is the worker pool, whose entire job is to
//! wait for work.
//!
//! [`close`](BoundedQueue::close) begins the drain: new pushes fail
//! with [`PushError::Closed`], already-admitted items keep flowing to
//! workers, and once the queue runs dry every blocked
//! [`pop`](BoundedQueue::pop) returns `None` — the worker exit signal.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a push was refused. The item comes back so the caller can
/// answer its waiters.
#[derive(Debug)]
pub enum PushError<T> {
    /// At capacity — classic backpressure, retry later.
    Full(T),
    /// Draining for shutdown — this queue will never admit again.
    Closed(T),
}

#[derive(Debug)]
struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// See the module docs.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    available: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                closed: false,
            }),
            available: Condvar::new(),
            cap,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Admit an item, or refuse without blocking. `Ok` carries the
    /// queue depth *after* the push (the retry-hint input).
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut s = self.lock();
        if s.closed {
            return Err(PushError::Closed(item));
        }
        if s.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        s.items.push_back(item);
        let depth = s.items.len();
        drop(s);
        self.available.notify_one();
        Ok(depth)
    }

    /// Block until an item is available or the queue is closed *and*
    /// empty (`None` — the drain is complete).
    pub fn pop(&self) -> Option<T> {
        let mut s = self.lock();
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self
                .available
                .wait(s)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Stop admitting; wake every blocked popper so the drain can
    /// finish.
    pub fn close(&self) {
        self.lock().closed = true;
        self.available.notify_all();
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_fills_to_cap_then_rejects_without_blocking() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_push(1).unwrap(), 1);
        assert_eq!(q.try_push(2).unwrap(), 2);
        match q.try_push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(3).unwrap(), 2);
    }

    #[test]
    fn close_drains_then_returns_none() {
        let q = BoundedQueue::new(4);
        q.try_push("a").unwrap();
        q.try_push("b").unwrap();
        q.close();
        match q.try_push("c") {
            Err(PushError::Closed(item)) => assert_eq!(item, "c"),
            other => panic!("expected Closed, got {other:?}"),
        }
        // Already-admitted items still drain, then the exit signal.
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_poppers() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.pop())
            })
            .collect();
        q.close();
        for h in handles {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn concurrent_producers_and_consumers_account_for_every_item() {
        let q = Arc::new(BoundedQueue::<u64>::new(1024));
        let producers: Vec<_> = (0..4u64)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..64u64 {
                        q.try_push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut expect: Vec<u64> = (0..4u64)
            .flat_map(|p| (0..64u64).map(move |i| p * 1000 + i))
            .collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }
}
