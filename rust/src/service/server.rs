//! The resident [`Service`]: shared pattern index, admission queue,
//! worker pool, in-flight coalescing, refresh-ahead.
//!
//! Request lifecycle (see ARCHITECTURE.md "Service tier" for the full
//! diagram):
//!
//! 1. The caller thread computes the request's
//!    [`ReuseKey`](crate::envadapt::ReuseKey) and probes the in-memory
//!    [`PatternIndex`] — a fresh-enough match is answered right there
//!    (**hit**, microseconds, never queued).
//! 2. A miss coalesces onto an identical in-flight solve when one
//!    exists; otherwise it must win a queue slot — a full queue is an
//!    *immediate* typed reject (`stage=queue, class=transient`) with a
//!    `retry_after_ms` hint, not a stall.
//! 3. A worker pops the job, re-checks waiter deadlines (expired work
//!    is answered with a typed timeout and never solved), tightens the
//!    retry policy's stage deadline to the remaining wall budget, and
//!    runs the existing [`Batch`] ladder. The result is broadcast to
//!    every coalesced waiter and pulled into the shared index so the
//!    next identical request is a hit.
//!
//! Waiting callers enforce their own deadline with `recv_timeout`, so a
//! deadline expiry returns a typed error even if the worker pool is
//! wedged.

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::envadapt::patterndb::unix_now;
use crate::envadapt::{
    Batch, OffloadRequest, PatternIndex, Pipeline, Plan, ReuseKey,
    ServiceLevel, StoredPattern,
};
use crate::obs::{self, SpanRecord, TraceHandoff, Tracer};
use crate::search::{
    FaultClass, FaultStats, OffloadError, RetryPolicy, SimClock, Stage,
};

use super::queue::{BoundedQueue, PushError};
use super::stats::{ServiceStats, StatsSnapshot};
use crate::store::StoreStatsSnapshot;
use super::{
    PlanRequest, PlanResponse, ServeClass, ServedPlan, ServiceConfig,
};

fn elapsed_us(start: Instant) -> u64 {
    start.elapsed().as_micros().min(u64::MAX as u128) as u64
}

/// Why a job sits in the queue.
enum JobKind {
    /// At least one caller is blocked on the answer.
    Foreground,
    /// Refresh-ahead re-search; nobody waits, the result just lands in
    /// the index.
    Refresh,
}

/// One blocked caller of [`Service::request`].
struct Waiter {
    tx: mpsc::Sender<PlanResponse>,
    deadline: Option<Instant>,
}

/// A queued miss.
struct Job {
    key: ReuseKey,
    req: OffloadRequest,
    enqueued: Instant,
    kind: JobKind,
    /// The admitting request's trace context; the worker re-enters it
    /// so the solve's spans land under the same `trace_id`.
    trace: Option<TraceHandoff>,
    /// Tracer timestamp at enqueue — the start of the `queue.wait`
    /// span the worker closes on pickup.
    trace_enqueued_us: u64,
}

struct Inner {
    cfg: ServiceConfig,
    backend: Box<dyn crate::search::Backend + Send + Sync>,
    index: Option<PatternIndex>,
    queue: BoundedQueue<Job>,
    /// Keys currently queued or being solved, with everyone waiting on
    /// each. Presence in this map is what coalescing checks.
    inflight: Mutex<HashMap<ReuseKey, Vec<Waiter>>>,
    stats: ServiceStats,
    clock: SimClock,
    tracer: Tracer,
    /// One shared retry-telemetry sink for every worker pipeline — the
    /// counters [`Service::stats`] surfaces. (Each job used to build a
    /// fresh `FaultStats` and drop it with the pipeline.)
    fault_stats: FaultStats,
}

/// What an index probe found.
enum Probe {
    Hit {
        rec: StoredPattern,
        /// Inside the refresh-ahead window: serve, but also re-search.
        refresh: bool,
    },
    Miss,
}

impl Inner {
    /// A pipeline over the service's backend/config, optionally wrapped
    /// in a retry policy sharing the service clock. Workers build one
    /// per job; the hit path builds one only to derive reuse keys.
    fn pipeline(
        &self,
        policy: Option<RetryPolicy>,
    ) -> Result<Pipeline<'_>, OffloadError> {
        let mut p =
            Pipeline::new(self.cfg.search.clone(), self.backend.as_ref())
                .map_err(|e| e.to_offload_error())?
                .with_fault_stats(self.fault_stats.clone());
        if let Some(dir) = &self.cfg.pattern_db {
            p = p.with_pattern_db(dir);
        }
        if let Some(policy) = policy {
            p = p
                .with_retry(policy)
                .map_err(|e| e.to_offload_error())?
                .with_clock(self.clock.clone());
        }
        Ok(p)
    }

    fn reuse_key(
        &self,
        req: &OffloadRequest,
    ) -> Result<ReuseKey, OffloadError> {
        Ok(self.pipeline(None)?.reuse_key_for(req))
    }

    /// Probe the index for a servable record. `count` feeds the index
    /// hit/miss counters; the coalescing double-check passes `false` so
    /// a request is never counted twice.
    fn probe(&self, app: &str, key: &ReuseKey, count: bool) -> Probe {
        let Some(idx) = &self.index else {
            return Probe::Miss;
        };
        let rec = if count {
            idx.lookup(app, key)
        } else {
            idx.get(app).filter(|r| r.matches(key))
        };
        let Some(rec) = rec else {
            return Probe::Miss;
        };
        match self.cfg.max_age {
            None => Probe::Hit {
                rec,
                refresh: false,
            },
            Some(max_age) => {
                let max_s = max_age.as_secs();
                match rec.age_secs(unix_now()) {
                    Some(age) if age <= max_s => {
                        let window =
                            (max_s as f64 * self.cfg.refresh_ahead) as u64;
                        Probe::Hit {
                            rec,
                            refresh: age > window,
                        }
                    }
                    // Unstamped records count as infinitely old, same
                    // as the pipeline's max-age policy. Either way the
                    // record *matched* the key — count it as a stale
                    // hit so operators can tell "cache too old" apart
                    // from "cache never had it".
                    _ => {
                        if count {
                            idx.store_handle().stats().note_stale_hit();
                        }
                        Probe::Miss
                    }
                }
            }
        }
    }

    /// Backlog-derived wait suggestion for a rejected caller.
    fn retry_after_ms(&self) -> u64 {
        let backlog = self.queue.len() as f64 + 1.0;
        let workers = self.cfg.workers.max(1) as f64;
        let ms = backlog * self.stats.avg_solve_ms() / workers;
        (ms.ceil() as u64).max(1)
    }

    /// Best-effort: enqueue a background re-search for `key` unless one
    /// is already in flight. A full queue drops the refresh silently —
    /// the caller was already served.
    fn schedule_refresh(&self, key: &ReuseKey, req: &OffloadRequest) {
        {
            let mut fl = self
                .inflight
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            if fl.contains_key(key) {
                return;
            }
            fl.insert(key.clone(), Vec::new());
        }
        let job = Job {
            key: key.clone(),
            req: req.clone(),
            enqueued: Instant::now(),
            kind: JobKind::Refresh,
            // The refresh rides the triggering request's trace, so one
            // exported tree shows the hit *and* the re-search it cost.
            trace: obs::handoff(),
            trace_enqueued_us: self.tracer.now_us(),
        };
        match self.queue.try_push(job) {
            Ok(_) => self.stats.refresh_scheduled(),
            Err(_) => {
                self.inflight
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .remove(key);
                self.stats.refresh_dropped();
            }
        }
    }

    /// Answer (and drop) every waiter registered under `key`.
    fn respond(
        &self,
        app: &str,
        key: &ReuseKey,
        class: ServeClass,
        result: Result<ServedPlan, OffloadError>,
    ) {
        let waiters = self
            .inflight
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .remove(key)
            .unwrap_or_default();
        for w in waiters {
            // A gone receiver just means that caller timed out already.
            let _ = w.tx.send(PlanResponse {
                app: app.to_string(),
                class,
                result: result.clone(),
                retry_after_ms: None,
                // The caller stamps its own submit-to-answer latency.
                latency_us: 0,
            });
        }
    }

    /// The effective wall deadline for a queued job: the *latest* among
    /// its waiters if every one is bounded, `None` if any waiter (or a
    /// refresh job, which has none) is unbounded.
    fn job_deadline(&self, key: &ReuseKey) -> Option<Instant> {
        let fl = self
            .inflight
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        let waiters = fl.get(key)?;
        if waiters.is_empty() {
            return None;
        }
        let mut latest = None;
        for w in waiters {
            let d = w.deadline?;
            latest = Some(match latest {
                None => d,
                Some(prev) if d > prev => d,
                Some(prev) => prev,
            });
        }
        latest
    }

    /// The retry policy a worker solve runs under: the configured one
    /// (or default, when a deadline forces one), with `stage_deadline_s`
    /// clamped to the remaining wall budget. This is the PR 6 seam — a
    /// simulated hung build burns the request's budget and trips its
    /// deadline instead of wedging a worker forever.
    fn effective_policy(
        &self,
        deadline: Option<Instant>,
    ) -> Option<RetryPolicy> {
        let remaining_s = deadline.map(|d| {
            d.saturating_duration_since(Instant::now())
                .as_secs_f64()
                .max(0.001)
        });
        if self.cfg.retry.is_none() && remaining_s.is_none() {
            return None;
        }
        let mut policy = self.cfg.retry.clone().unwrap_or_default();
        if let Some(rem) = remaining_s {
            policy.stage_deadline_s =
                Some(policy.stage_deadline_s.map_or(rem, |s| s.min(rem)));
        }
        Some(policy)
    }

    /// Run one miss solve through the batch ladder and shape the
    /// outcome.
    fn run_ladder(
        &self,
        job: &Job,
        policy: Option<RetryPolicy>,
    ) -> Result<ServedPlan, OffloadError> {
        let pipeline = self.pipeline(policy)?;
        let report = Batch::new(&pipeline).with(job.req.clone()).run();
        let Some(entry) = report.entries.into_iter().next() else {
            return Err(OffloadError::new(
                Stage::Select,
                FaultClass::Permanent,
                "batch cycle produced no entry",
            ));
        };
        match entry.plan {
            Some(plan) => Ok(ServedPlan {
                best_pattern: plan.best_loops(),
                label: plan.label(),
                speedup: plan.speedup(),
                blocks: plan.block_count() as u64,
                cached: plan.is_cached(),
                verified_ok: plan.verified_ok(),
                service: entry.service,
                refresh_ahead: false,
            }),
            None => Err(entry
                .outcomes
                .into_iter()
                .find_map(|o| o.error)
                .unwrap_or_else(|| {
                    OffloadError::new(
                        Stage::Analysis,
                        FaultClass::Permanent,
                        entry.error.unwrap_or_else(|| {
                            "request could not be served".into()
                        }),
                    )
                })),
        }
    }

    fn serve_job(&self, job: Job) {
        // Re-enter the admitting request's trace on this worker thread
        // and close out the time the job spent queued.
        let _trace = obs::enter(&job.trace);
        obs::closed_span("queue.wait", job.trace_enqueued_us);
        let deadline = match job.kind {
            JobKind::Foreground => self.job_deadline(&job.key),
            JobKind::Refresh => None,
        };
        if let Some(d) = deadline {
            if Instant::now() >= d {
                // Every waiter's budget expired while the job sat
                // queued: answer with a typed timeout, skip the solve.
                let waited = job.enqueued.elapsed().as_millis();
                let err = OffloadError::new(
                    Stage::Queue,
                    FaultClass::Timeout,
                    format!(
                        "deadline expired after {waited}ms in queue; \
                         solve skipped"
                    ),
                );
                self.respond(
                    &job.req.app,
                    &job.key,
                    ServeClass::Miss,
                    Err(err),
                );
                return;
            }
        }
        let policy = self.effective_policy(deadline);
        let t0 = Instant::now();
        let result = {
            let mut solve = obs::span("solve");
            solve.note(|| job.req.app.clone());
            self.run_ladder(&job, policy)
        };
        self.stats.solve(elapsed_us(t0), result.is_err());
        if let Ok(plan) = &result {
            if plan.service != ServiceLevel::Full {
                self.stats.degraded();
            }
        }
        // The pipeline wrote through the shared sharded store, so the
        // index already sees the record. The per-shard refresh here only
        // re-syncs against *external* writers (another process on the
        // same DB dir) and touches one shard, never the hit path's read
        // locks on the other fifteen.
        if let Some(idx) = &self.index {
            let _ = idx.refresh(&job.req.app);
        }
        if matches!(job.kind, JobKind::Refresh) {
            self.stats.refresh_done();
        }
        self.respond(&job.req.app, &job.key, ServeClass::Miss, result);
    }
}

fn worker_loop(inner: Arc<Inner>) {
    while let Some(job) = inner.queue.pop() {
        inner.serve_job(job);
    }
}

/// The resident offload service. See the [module docs](self) and
/// [`crate::service`] for the design; construct with
/// [`Service::start`], submit with [`Service::request`], observe with
/// [`Service::stats`], stop with [`Service::shutdown`] (also run on
/// drop).
pub struct Service {
    inner: Arc<Inner>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Service {
    /// Validate the config, build its bundled backend, and start the
    /// worker pool.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let backend = cfg.backend.build();
        Service::with_backend(cfg, backend)
    }

    /// Like [`Service::start`] but with a caller-supplied backend — the
    /// test seam for instrumented backends (gated measures, fault
    /// injection).
    pub fn with_backend(
        cfg: ServiceConfig,
        backend: Box<dyn crate::search::Backend + Send + Sync>,
    ) -> Result<Service> {
        let tracer = Tracer::new(&cfg.trace);
        Service::build(cfg, backend, SimClock::new(), tracer)
    }

    /// Like [`Service::with_backend`] but with both the retry clock and
    /// the tracer on the caller's virtual clock — the determinism seam:
    /// a seeded fault run against a [`crate::search::FaultyBackend`]
    /// sharing `clock` produces a byte-identical span tree every run.
    pub fn with_backend_on_clock(
        cfg: ServiceConfig,
        backend: Box<dyn crate::search::Backend + Send + Sync>,
        clock: SimClock,
    ) -> Result<Service> {
        let tracer = Tracer::with_sim_clock(&cfg.trace, clock.clone());
        Service::build(cfg, backend, clock, tracer)
    }

    fn build(
        cfg: ServiceConfig,
        backend: Box<dyn crate::search::Backend + Send + Sync>,
        clock: SimClock,
        tracer: Tracer,
    ) -> Result<Service> {
        cfg.validate()
            .map_err(|e| anyhow::anyhow!("invalid service config: {e}"))?;
        let index = match &cfg.pattern_db {
            Some(dir) => {
                let idx = PatternIndex::open(dir)?;
                // The store handle is shared process-wide, so the
                // capacity set here also governs the workers' pipeline
                // writes.
                idx.store_handle().set_capacity(cfg.db_capacity);
                Some(idx)
            }
            None => None,
        };
        let queue = BoundedQueue::new(cfg.queue_cap);
        let worker_count = cfg.workers;
        let inner = Arc::new(Inner {
            cfg,
            backend,
            index,
            queue,
            inflight: Mutex::new(HashMap::new()),
            stats: ServiceStats::new(),
            clock,
            tracer,
            fault_stats: FaultStats::new(),
        });
        let mut handles = Vec::with_capacity(worker_count);
        for i in 0..worker_count {
            let inner = Arc::clone(&inner);
            let handle = std::thread::Builder::new()
                .name(format!("offload-worker-{i}"))
                .spawn(move || worker_loop(inner))
                .map_err(|e| {
                    anyhow::anyhow!("spawning worker {i}: {e}")
                })?;
            handles.push(handle);
        }
        Ok(Service {
            inner,
            workers: Mutex::new(handles),
        })
    }

    /// Submit one request and block until it is answered, rejected, or
    /// its deadline expires. Always returns — every failure mode is a
    /// typed [`OffloadError`] in the response.
    pub fn request(&self, preq: PlanRequest) -> PlanResponse {
        let start = Instant::now();
        let inner = &self.inner;
        inner.stats.request();
        let app = preq.app.clone();
        // Root span for the whole request; lives until this function
        // returns, so its duration is the submit-to-answer latency.
        let _root = inner.tracer.trace("request", &app);
        // Admission: reuse-key derivation, index probe, queue decision.
        // Ended explicitly before blocking on a worker; every other
        // return path ends it (and the root) by dropping out of scope.
        let mut admission = Some(obs::span("admission"));
        let fail = |result: OffloadError| PlanResponse {
            app: preq.app.clone(),
            class: ServeClass::Miss,
            result: Err(result),
            retry_after_ms: None,
            latency_us: elapsed_us(start),
        };

        let oreq = match OffloadRequest::builder(preq.app.as_str())
            .source(preq.source.as_str())
            .entry(preq.entry.as_str())
            .seed(preq.seed)
            .func_blocks(preq.func_blocks)
            .build()
        {
            Ok(r) => r,
            Err(e) => return fail(e.to_offload_error()),
        };
        let key = match inner.reuse_key(&oreq) {
            Ok(k) => k,
            Err(e) => return fail(e),
        };

        // Hit path: answered on this thread, never queued.
        if let Probe::Hit { rec, refresh } = inner.probe(&app, &key, true)
        {
            if refresh {
                inner.schedule_refresh(&key, &oreq);
            }
            let latency_us = elapsed_us(start);
            inner.stats.hit(latency_us);
            return PlanResponse {
                app,
                class: ServeClass::Hit,
                result: Ok(served_from_record(rec, refresh)),
                retry_after_ms: None,
                latency_us,
            };
        }

        // Miss path: coalesce or win a queue slot.
        let deadline = preq
            .deadline_ms
            .map(|ms| start + Duration::from_millis(ms));
        let (tx, rx) = mpsc::channel();
        {
            let mut fl = inner
                .inflight
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            if let Some(waiters) = fl.get_mut(&key) {
                waiters.push(Waiter { tx, deadline });
                inner.stats.coalesced();
            } else {
                // Double-check the index under the in-flight lock: a
                // worker may have finished this key between the probe
                // above and now (it removes the in-flight entry before
                // we can see its index refresh, so no-entry + indexed
                // record means "just completed").
                if let Probe::Hit { rec, refresh } =
                    inner.probe(&app, &key, false)
                {
                    drop(fl);
                    if refresh {
                        inner.schedule_refresh(&key, &oreq);
                    }
                    let latency_us = elapsed_us(start);
                    inner.stats.hit(latency_us);
                    return PlanResponse {
                        app,
                        class: ServeClass::Hit,
                        result: Ok(served_from_record(rec, refresh)),
                        retry_after_ms: None,
                        latency_us,
                    };
                }
                fl.insert(key.clone(), vec![Waiter { tx, deadline }]);
                drop(fl);
                let job = Job {
                    key: key.clone(),
                    req: oreq,
                    enqueued: start,
                    kind: JobKind::Foreground,
                    trace: obs::handoff(),
                    trace_enqueued_us: inner.tracer.now_us(),
                };
                if let Err(err) = inner.queue.try_push(job) {
                    inner
                        .inflight
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .remove(&key);
                    inner.stats.rejected();
                    let (msg, hint) = match err {
                        PushError::Full(_) => {
                            let hint = inner.retry_after_ms();
                            (
                                format!(
                                    "admission queue full ({} slots); \
                                     retry in ~{hint}ms",
                                    inner.queue.capacity()
                                ),
                                Some(hint),
                            )
                        }
                        PushError::Closed(_) => (
                            "service is draining; request not admitted"
                                .to_string(),
                            None,
                        ),
                    };
                    return PlanResponse {
                        app,
                        class: ServeClass::Miss,
                        result: Err(OffloadError::new(
                            Stage::Queue,
                            FaultClass::Transient,
                            msg,
                        )),
                        retry_after_ms: hint,
                        latency_us: elapsed_us(start),
                    };
                }
            }
        }

        // Admission is over; what follows is the wait, which the worker
        // accounts as `queue.wait` + `solve` under this same trace.
        admission.take();

        // Wait for the worker broadcast, bounded by our own deadline so
        // a wedged pool can never hang the caller.
        let received = match deadline {
            None => rx.recv().ok(),
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    rx.try_recv().ok()
                } else {
                    rx.recv_timeout(d - now).ok()
                }
            }
        };
        match received {
            Some(mut resp) => {
                resp.latency_us = elapsed_us(start);
                match &resp.result {
                    Ok(_) => inner.stats.miss(resp.latency_us),
                    Err(e) if e.class == FaultClass::Timeout => {
                        inner.stats.timeout()
                    }
                    // Solve failures are already counted by the worker
                    // (solve_errors); rejects never reach this channel.
                    Err(_) => {}
                }
                resp
            }
            None if deadline.is_some() => {
                inner.stats.timeout();
                let ms = preq.deadline_ms.unwrap_or(0);
                PlanResponse {
                    app,
                    class: ServeClass::Miss,
                    result: Err(OffloadError::new(
                        Stage::Queue,
                        FaultClass::Timeout,
                        format!(
                            "deadline of {ms}ms expired after {}ms",
                            start.elapsed().as_millis()
                        ),
                    )),
                    retry_after_ms: None,
                    latency_us: elapsed_us(start),
                }
            }
            None => {
                // No deadline and a disconnected channel: the service
                // stopped under us. Typed, not a hang.
                inner.stats.rejected();
                PlanResponse {
                    app,
                    class: ServeClass::Miss,
                    result: Err(OffloadError::new(
                        Stage::Queue,
                        FaultClass::Transient,
                        "service stopped before the request completed",
                    )),
                    retry_after_ms: None,
                    latency_us: elapsed_us(start),
                }
            }
        }
    }

    /// Point-in-time counters and latency quantiles.
    pub fn stats(&self) -> StatsSnapshot {
        let inner = &self.inner;
        let (records, store) = match &inner.index {
            Some(idx) => {
                (idx.len(), idx.store_handle().stats().snapshot())
            }
            None => (0, StoreStatsSnapshot::default()),
        };
        let inflight = inner
            .inflight
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .len();
        inner.stats.snapshot(
            inner.queue.len(),
            inflight,
            records,
            store,
            inner.fault_stats.snapshot(),
        )
    }

    /// Every span currently retained by the trace collector, oldest
    /// first — what the `trace` protocol op and `repro trace` read.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.inner.tracer.spans()
    }

    /// The service's tracer (shared collector; clones observe the same
    /// spans).
    pub fn tracer(&self) -> &Tracer {
        &self.inner.tracer
    }

    /// The virtual clock worker retry policies run on — tests advance
    /// it to burn simulated backoff/hang time.
    pub fn clock(&self) -> SimClock {
        self.inner.clock.clone()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.inner.cfg
    }

    /// Graceful drain: stop admitting, let workers finish everything
    /// already queued, join them. Anything still queued afterwards
    /// (possible only with zero workers) is answered with a typed
    /// reject so no caller is left hanging. Idempotent; also run on
    /// drop.
    pub fn shutdown(&self) {
        self.inner.queue.close();
        let handles: Vec<_> = self
            .workers
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
        while let Some(job) = self.inner.queue.pop() {
            let err = OffloadError::new(
                Stage::Queue,
                FaultClass::Transient,
                "service shut down before the request was served",
            );
            self.inner.respond(
                &job.req.app,
                &job.key,
                ServeClass::Miss,
                Err(err),
            );
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Shape an indexed record into the response plan (service level Full —
/// a hit is the ladder's best case by construction).
fn served_from_record(rec: StoredPattern, refresh: bool) -> ServedPlan {
    let verified_ok = rec.verified != Some(false);
    let speedup = rec.speedup;
    let blocks = rec.blocks;
    let plan = Plan::Cached(rec);
    ServedPlan {
        best_pattern: plan.best_loops(),
        label: plan.label(),
        speedup,
        blocks,
        cached: true,
        verified_ok,
        service: ServiceLevel::Full,
        refresh_ahead: refresh,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::BackendKind;
    use crate::util::tempdir::TempDir;

    const TINY: &str = "
#define N 128
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

    #[test]
    fn invalid_config_is_refused_at_start() {
        let cfg = ServiceConfig {
            queue_cap: 0,
            ..ServiceConfig::default()
        };
        assert!(Service::start(cfg).is_err());
        let cfg = ServiceConfig {
            refresh_ahead: 1.5,
            ..ServiceConfig::default()
        };
        assert!(Service::start(cfg).is_err());
    }

    #[test]
    fn cold_solve_then_warm_hit() {
        let dir = TempDir::new("svc-warm").unwrap();
        let cfg = ServiceConfig {
            pattern_db: Some(dir.path().to_path_buf()),
            workers: 1,
            ..ServiceConfig::default()
        };
        let svc = Service::start(cfg).unwrap();
        let cold = svc.request(PlanRequest::new("tiny", TINY));
        assert!(cold.ok(), "cold solve failed: {:?}", cold.result);
        assert_eq!(cold.class, ServeClass::Miss);
        let warm = svc.request(PlanRequest::new("tiny", TINY));
        assert!(warm.is_hit(), "expected a hit: {:?}", warm.result);
        let cold_plan = cold.result.unwrap();
        let warm_plan = warm.result.unwrap();
        assert_eq!(cold_plan.best_pattern, warm_plan.best_pattern);
        assert!(warm_plan.cached);
        let snap = svc.stats();
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.solves, 1);
        svc.shutdown();
    }

    #[test]
    fn zero_workers_fill_queue_then_typed_reject() {
        let cfg = ServiceConfig {
            workers: 0,
            queue_cap: 1,
            backend: BackendKind::Cpu,
            ..ServiceConfig::default()
        };
        let svc = Service::start(cfg).unwrap();
        // Two distinct keys, both with an expired budget so the callers
        // return immediately while the jobs stay queued.
        let mut a = PlanRequest::new("a", TINY);
        a.deadline_ms = Some(0);
        let ra = svc.request(a);
        assert!(ra.is_timeout(), "expected timeout: {:?}", ra.result);
        let mut b = PlanRequest::new("b", TINY);
        b.entry = "other".into();
        b.deadline_ms = Some(0);
        let rb = svc.request(b);
        assert!(
            rb.is_rejected(),
            "expected queue-full reject: {:?}",
            rb.result
        );
        assert!(rb.retry_after_ms.is_some());
        assert_eq!(svc.stats().rejected, 1);
        svc.shutdown();
    }

    #[test]
    fn shutdown_answers_still_queued_jobs() {
        let cfg = ServiceConfig {
            workers: 0,
            queue_cap: 4,
            backend: BackendKind::Cpu,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(Service::start(cfg).unwrap());
        let svc2 = Arc::clone(&svc);
        let waiter = std::thread::spawn(move || {
            svc2.request(PlanRequest::new("queued", TINY))
        });
        // Wait until the job is admitted, then drain.
        while svc.stats().queue_depth == 0 {
            std::thread::yield_now();
        }
        svc.shutdown();
        let resp = waiter.join().unwrap();
        assert!(
            resp.is_rejected(),
            "expected drain reject: {:?}",
            resp.result
        );
    }
}
