//! GPU device models (the mixed-destination evaluation's second board).
//!
//! The follow-on evaluations (arXiv:2011.12431) put an NVIDIA data-center
//! board next to the Arria10 in the verification environment. The model
//! here is deliberately coarse — SM/core counts, clock, memory and PCIe
//! bandwidth, launch/DMA latencies, and an *automatic-offload* efficiency
//! factor — because the point is destination *selection*, not cycle
//! accuracy: what matters is that trig-dense, massively parallel loops
//! land on the GPU while deep spatialized MAC pipelines stay on the FPGA.

use crate::minic::OpCounts;

/// Static description of a GPU destination.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuDevice {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sms: u64,
    /// FP32 cores per SM.
    pub cores_per_sm: u64,
    /// Sustained SM clock, Hz.
    pub clock_hz: f64,
    /// Resident threads per SM at full occupancy.
    pub threads_per_sm: u64,
    /// Effective device-memory bandwidth, bytes/s.
    pub mem_bytes_per_sec: f64,
    /// Effective host↔device bandwidth (PCIe), bytes/s.
    pub pcie_bytes_per_sec: f64,
    /// Fixed per-DMA-transfer latency, seconds.
    pub dma_latency_s: f64,
    /// Fixed kernel-launch overhead, seconds.
    pub launch_latency_s: f64,
    /// Fraction of peak ALU throughput an *automatically* generated
    /// (OpenACC-style, no hand tuning) kernel sustains.
    pub auto_efficiency: f64,
    /// Dependent-chain expansion: issue cycles × this factor is the
    /// latency of one thread's serial chain (no ILP, exposed memory
    /// latency at low occupancy).
    pub latency_expansion: f64,
    /// Modeled destination build time per pattern, seconds — an nvcc /
    /// OpenACC compile, not a place-and-route: minutes, not hours.
    pub build_seconds: f64,
}

/// NVIDIA Tesla T4 (Turing TU104, the NFV-server inference board of the
/// mixed-destination papers' era): 40 SMs × 64 FP32 cores, 16 GB GDDR6.
pub const TESLA_T4: GpuDevice = GpuDevice {
    name: "NVIDIA Tesla T4",
    sms: 40,
    cores_per_sm: 64,
    clock_hz: 1.59e9,
    threads_per_sm: 1024,
    mem_bytes_per_sec: 240.0e9, // ~75% of the 320 GB/s GDDR6 peak
    pcie_bytes_per_sec: 12.0e9, // PCIe Gen3 x16 effective
    dma_latency_s: 5.0e-6,
    launch_latency_s: 5.0e-6,
    auto_efficiency: 0.25,
    latency_expansion: 8.0,
    build_seconds: 60.0,
};

// Per-op issue costs in SM cycles (per thread, FP32). Transcendentals hit
// the special-function units — the structural edge over both the CPU
// (42-cycle libm calls) and the FPGA (CORDIC pipelines burning soft
// logic): trig-dense loops are where the GPU destination wins.
const CYC_FADD: f64 = 1.0;
const CYC_FMUL: f64 = 1.0;
const CYC_FDIV: f64 = 8.0;
const CYC_TRIG: f64 = 4.0;
const CYC_IOP: f64 = 0.5;
const CYC_CMP: f64 = 0.5;
const CYC_READ: f64 = 2.0; // coalesced global load, amortized
const CYC_WRITE: f64 = 2.0;

impl GpuDevice {
    /// Total FP32 cores.
    pub fn cores(&self) -> u64 {
        self.sms * self.cores_per_sm
    }

    /// Cores an automatically generated kernel effectively keeps busy.
    pub fn effective_lanes(&self) -> f64 {
        (self.cores() as f64 * self.auto_efficiency).max(1.0)
    }

    /// Threads resident across the device at full occupancy.
    pub fn resident_threads(&self) -> u64 {
        self.sms * self.threads_per_sm
    }

    /// Issue cycles for an op-count record (throughput view, one lane).
    pub fn issue_cycles(&self, ops: &OpCounts) -> f64 {
        ops.f_add as f64 * CYC_FADD
            + ops.f_mul as f64 * CYC_FMUL
            + ops.f_div as f64 * CYC_FDIV
            + ops.f_trig as f64 * CYC_TRIG
            + ops.i_op as f64 * CYC_IOP
            + ops.cmp as f64 * CYC_CMP
            + ops.reads as f64 * CYC_READ
            + ops.writes as f64 * CYC_WRITE
    }

    /// One direction of a host↔device DMA.
    pub fn dma_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.dma_latency_s + bytes as f64 / self.pcie_bytes_per_sec
    }

    /// Full launch overhead for one kernel invocation moving `bytes_in`
    /// then `bytes_out`.
    pub fn launch_overhead(&self, bytes_in: u64, bytes_out: u64) -> f64 {
        self.launch_latency_s
            + self.dma_time(bytes_in)
            + self.dma_time(bytes_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t4_figures_sane() {
        let g = &TESLA_T4;
        assert_eq!(g.cores(), 2560);
        assert_eq!(g.resident_threads(), 40960);
        assert!(g.effective_lanes() > 100.0);
        assert!(g.effective_lanes() < g.cores() as f64);
        assert!(g.build_seconds < 3600.0, "GPU builds are not HLS compiles");
    }

    #[test]
    fn trig_is_cheap_relative_to_cpu() {
        // The SFU edge: a trig op costs 4 issue cycles here vs 42 on the
        // modeled Xeon — the discriminator that routes trig-dense loops
        // to the GPU destination.
        let ops = OpCounts {
            f_trig: 100,
            ..Default::default()
        };
        let g = &TESLA_T4;
        assert_eq!(g.issue_cycles(&ops), 400.0);
    }

    #[test]
    fn launch_overhead_sums_parts() {
        let g = &TESLA_T4;
        let t = g.launch_overhead(1_000, 2_000);
        let expect =
            g.launch_latency_s + g.dma_time(1_000) + g.dma_time(2_000);
        assert!((t - expect).abs() < 1e-12);
        assert_eq!(g.dma_time(0), 0.0);
    }
}
