//! GPU destination: the mixed-environment board next to the FPGA
//! (ROADMAP item, arXiv:2011.12431 direction), built as simulation like
//! [`crate::fpga`].
//!
//! * [`device`] — the board model ([`TESLA_T4`]): SMs, clocks,
//!   bandwidths, launch/DMA latencies, and the automatic-offload
//!   efficiency factor.
//! * [`sim`] — the per-pattern performance model: one CUDA thread per
//!   iteration of the offloaded loop, worst-of (throughput, chain
//!   latency × waves, memory bandwidth) per launch, PCIe transfers per
//!   entry.
//!
//! **Model assumptions** (kept deliberately coarse — the funnel needs a
//! *ranking*, not cycle accuracy):
//!
//! 1. Automatic offloading does not restructure loops: the annotated
//!    loop's iterations become the grid; nested loops run serially per
//!    thread (OpenACC `parallel loop` without `collapse`).
//! 2. Transcendentals run on the SFUs (4 issue cycles) — the GPU's
//!    structural edge over the Xeon's 42-cycle libm calls and the
//!    FPGA's soft-logic CORDIC pipelines.
//! 3. Carried loops serialize into one thread; reductions pay a 2×
//!    tree/atomics penalty; only `Independent` loops parallelize fully.
//! 4. There is no resource-fit failure mode and no hours-long compile:
//!    a pattern's destination build is ~a minute of nvcc, so GPU
//!    automation cycles are minutes where FPGA cycles are half a day.
//!
//! Functional verification is destination-independent (outlined-kernel
//! interpretation, [`crate::fpga::exec`]) and is shared by all backends.
//!
//! ```
//! use fpga_offload::gpu::TESLA_T4;
//! use fpga_offload::minic::OpCounts;
//!
//! // The SFU edge: one trig op costs 4 issue cycles here vs 42 on the
//! // modeled Xeon — the discriminator that routes trig-dense loops to
//! // the GPU destination.
//! let trig = OpCounts { f_trig: 100, ..Default::default() };
//! assert_eq!(TESLA_T4.issue_cycles(&trig), 400.0);
//! assert_eq!(TESLA_T4.cores(), 2560);
//! ```

pub mod device;
pub mod sim;

pub use device::{GpuDevice, TESLA_T4};
pub use sim::simulate;
