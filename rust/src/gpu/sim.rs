//! GPU performance simulator: the verification-environment measurement
//! of one offload pattern on a GPU destination, mirroring
//! [`crate::fpga::sim`] in shape (same [`PatternTiming`] output, same
//! per-loop `entries × [launch + DMA + compute]` decomposition) but not
//! in physics.
//!
//! Automatic offloading maps the *offloaded loop's own iterations* to
//! CUDA threads — an OpenACC `parallel loop` on the annotated statement,
//! no restructuring, no `collapse` — so everything nested inside one
//! iteration runs serially in its thread. Per launch the model takes the
//! worst of three bounds:
//!
//! * **throughput** — total issue cycles over the lanes an automatically
//!   generated kernel keeps busy ([`GpuDevice::effective_lanes`]);
//! * **latency** — one thread's dependent chain
//!   (`issue × latency_expansion`), times the number of occupancy waves;
//! * **memory** — subtree bytes over effective device bandwidth.
//!
//! Dependence classes from [`crate::analysis::depend`] steer the mapping:
//! `Independent` parallelizes fully, `Reduction` pays a tree/atomics
//! factor, and `Carried` loops collapse to a single serial thread — a
//! GPU catastrophe the funnel's verified speedup will reject, which is
//! exactly the right answer for a carried loop.
//!
//! What this model deliberately has that the FPGA's does not: no resource
//! fit check (grids always "fit") and no hours-long compile — the
//! destination build is [`GpuDevice::build_seconds`] of nvcc, so a GPU
//! automation cycle is minutes, not half a day.

use crate::analysis::{Analysis, Dependence};
use crate::codegen::KernelIr;
use crate::cpu::CpuModel;
use crate::fpga::{subtree_ids, LoopTiming, PatternTiming, SimError};
use crate::hls::ResourceEstimate;
use crate::minic::ast::LoopId;
use crate::minic::OpCounts;

use super::device::GpuDevice;

/// Extra issue/latency factor for reduction loops (tree combine +
/// atomics on the way out).
const REDUCTION_PENALTY: f64 = 2.0;

/// Simulate a pattern of offloaded kernels on a GPU destination.
///
/// Returns the same [`PatternTiming`] the FPGA simulator produces so the
/// measurement funnel and the mixed-destination selector can compare the
/// two directly; `combined` stays at the zero [`ResourceEstimate`] — a
/// GPU pattern consumes no FPGA fabric.
pub fn simulate(
    analysis: &Analysis,
    kernels: &[KernelIr],
    cpu: &CpuModel,
    gpu: &GpuDevice,
) -> Result<PatternTiming, SimError> {
    // Disjointness: no offloaded loop may contain another offloaded loop
    // (same rule as the FPGA destination — one kernel per region).
    let offloaded: Vec<LoopId> = kernels.iter().map(|k| k.loop_id).collect();
    for k in kernels {
        let subtree = subtree_ids(analysis, k.loop_id);
        for other in &offloaded {
            if *other != k.loop_id && subtree.contains(other) {
                return Err(SimError::OverlappingLoops(k.loop_id, *other));
            }
        }
    }

    let cpu_baseline_s = cpu.time(&analysis.profile.total);

    let mut offloaded_ops = OpCounts::default();
    let mut loops = Vec::new();
    for k in kernels {
        let lp = analysis
            .profile
            .loop_profile(k.loop_id)
            .ok_or(SimError::ColdLoop(k.loop_id))?;
        offloaded_ops = offloaded_ops.plus(&lp.ops);

        let entries = lp.entries.max(1);
        // Grid size: iterations of the offloaded loop itself per launch.
        let threads = (lp.trips / entries).max(1);
        // Issue cycles of one launch's whole subtree, and of one thread.
        let issue_launch = gpu.issue_cycles(&lp.ops) / entries as f64;
        let per_thread = issue_launch / threads as f64;

        let penalty = match &k.dependence {
            Dependence::Reduction(_) => REDUCTION_PENALTY,
            _ => 1.0,
        };

        // Throughput bound: lanes cap at both the hardware and the
        // launch's actual thread count (8 threads use 8 cores, period).
        let lanes = gpu.effective_lanes().min(threads as f64);
        let alu_s = issue_launch * penalty / (lanes * gpu.clock_hz);

        // Latency bound: one thread's dependent chain per wave; a
        // carried loop serializes the entire launch into one chain.
        let lat_s = match &k.dependence {
            Dependence::Carried(_) => {
                issue_launch * gpu.latency_expansion / gpu.clock_hz
            }
            _ => {
                let waves = threads.div_ceil(gpu.resident_threads()).max(1);
                per_thread * gpu.latency_expansion * penalty
                    * waves as f64
                    / gpu.clock_hz
            }
        };

        // Memory bound: subtree traffic per launch at device bandwidth.
        let mem_s = (lp.ops.bytes() as f64 / entries as f64)
            / gpu.mem_bytes_per_sec;

        let compute_s = alu_s.max(lat_s).max(mem_s) * entries as f64;
        let transfer_s = entries as f64
            * gpu.launch_overhead(k.bytes_in(), k.bytes_out());

        loops.push(LoopTiming {
            loop_id: k.loop_id,
            entries,
            slots: threads,
            compute_s,
            transfer_s,
            total_s: compute_s + transfer_s,
        });
    }

    let rest_ops = analysis.profile.total.saturating_sub(&offloaded_ops);
    let cpu_rest_s = cpu.time(&rest_ops);
    let gpu_s: f64 = loops.iter().map(|l| l.total_s).sum();
    let pattern_s = cpu_rest_s + gpu_s;
    let speedup = if pattern_s > 0.0 {
        cpu_baseline_s / pattern_s
    } else {
        f64::INFINITY
    };

    Ok(PatternTiming {
        cpu_baseline_s,
        cpu_rest_s,
        loops,
        pattern_s,
        speedup,
        combined: ResourceEstimate::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::split;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::gpu::TESLA_T4;
    use crate::minic::parse;

    /// A trig-dense wide loop (GPU-friendly), a tiny frequently-entered
    /// copy loop (transfer-dominated), and a carried recurrence
    /// (GPU-hostile).
    const SRC: &str = "
#define N 4096
#define REP 64
float a[N]; float b[N]; float c[N]; float acc[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.0004 - 0.8; }       // L0 init
    for (int i = 0; i < N; i++) {                                  // L1 trig
        b[i] = sin(a[i]) * cos(a[i]) + sqrt(a[i] * a[i] + 1.0);
    }
    for (int r = 0; r < REP; r++) {                                // L2 outer
        for (int i = 0; i < 8; i++) { c[i] = b[i]; }               // L3 tiny copy
    }
    for (int i = 1; i < N; i++) { acc[i] = acc[i - 1] + b[i]; }    // L4 carried
    return 0;
}";

    fn setup() -> (crate::minic::Program, Analysis) {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        (prog, an)
    }

    fn kernel(
        prog: &crate::minic::Program,
        an: &Analysis,
        id: u32,
    ) -> KernelIr {
        split(prog, an.loop_by_id(LoopId(id)).unwrap())
            .unwrap()
            .kernel
    }

    #[test]
    fn wide_trig_loop_speeds_up() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 1);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &TESLA_T4).unwrap();
        assert!(
            t.speedup > 1.2,
            "wide trig loop should win on the GPU: {:.2}x",
            t.speedup
        );
        assert_eq!(t.loops[0].entries, 1);
        assert_eq!(t.loops[0].slots, 4096);
    }

    #[test]
    fn frequently_entered_tiny_loop_pays_launch_tax() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 3);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &TESLA_T4).unwrap();
        assert_eq!(t.loops[0].entries, 64);
        // 64 launches of an 8-element copy: transfers dwarf compute and
        // the pattern must lose.
        assert!(t.loops[0].transfer_s > t.loops[0].compute_s * 10.0);
        assert!(t.speedup < 1.0, "got {:.3}x", t.speedup);
    }

    #[test]
    fn carried_loop_serializes_and_loses() {
        let (prog, an) = setup();
        let k4 = kernel(&prog, &an, 4);
        assert!(matches!(k4.dependence, Dependence::Carried(_)));
        let t4 =
            simulate(&an, &[k4], &XEON_BRONZE_3104, &TESLA_T4).unwrap();
        // One serial GPU thread is far slower than the Xeon on the same
        // chain; the carried pattern must not be selected.
        assert!(t4.speedup < 1.0, "got {:.3}x", t4.speedup);
        let t1 = simulate(
            &an,
            &[kernel(&prog, &an, 1)],
            &XEON_BRONZE_3104,
            &TESLA_T4,
        )
        .unwrap();
        assert!(t1.loops[0].compute_s < t4.loops[0].compute_s);
    }

    #[test]
    fn overlapping_pattern_rejected() {
        let (prog, an) = setup();
        let k2 = kernel(&prog, &an, 2);
        let k3 = kernel(&prog, &an, 3);
        let err = simulate(&an, &[k2, k3], &XEON_BRONZE_3104, &TESLA_T4)
            .unwrap_err();
        assert!(matches!(err, SimError::OverlappingLoops(..)));
    }

    #[test]
    fn empty_pattern_is_baseline() {
        let (_prog, an) = setup();
        let t = simulate(&an, &[], &XEON_BRONZE_3104, &TESLA_T4).unwrap();
        assert!((t.speedup - 1.0).abs() < 1e-9);
        assert_eq!(t.loops.len(), 0);
        assert_eq!(t.combined, ResourceEstimate::default());
    }

    #[test]
    fn gpu_pattern_consumes_no_fpga_fabric() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 1);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &TESLA_T4).unwrap();
        assert_eq!(t.combined, ResourceEstimate::default());
    }
}
