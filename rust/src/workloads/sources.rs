//! Embedded MiniC sources of the evaluated applications.
//!
//! The `.c` files live in `rust/src/workloads/c/` and are compiled into
//! the binary so the coordinator is self-contained (no runtime file
//! dependencies beyond the AOT artifacts).

/// HPEC tdfir — 36 loops (paper §5.1.2).
pub const TDFIR_C: &str = include_str!("c/tdfir.c");

/// Parboil MRI-Q — 16 loops (paper §5.1.2).
pub const MRIQ_C: &str = include_str!("c/mriq.c");

/// Sobel edge detection — the extra IoT-imaging workload.
pub const SOBEL_C: &str = include_str!("c/sobel.c");

/// Source lookup by app name.
pub fn source(app: &str) -> Option<&'static str> {
    match app {
        "tdfir" => Some(TDFIR_C),
        "mriq" => Some(MRIQ_C),
        "sobel" => Some(SOBEL_C),
        _ => None,
    }
}

/// All bundled app names.
pub const APPS: &[&str] = &["tdfir", "mriq", "sobel"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::{parse, typecheck};

    #[test]
    fn tdfir_has_exactly_36_loops() {
        let prog = parse(TDFIR_C).unwrap();
        assert_eq!(prog.loop_count, 36, "paper §5.1.2: tdfir has 36 loops");
    }

    #[test]
    fn mriq_has_exactly_16_loops() {
        let prog = parse(MRIQ_C).unwrap();
        assert_eq!(prog.loop_count, 16, "paper §5.1.2: MRI-Q has 16 loops");
    }

    #[test]
    fn sobel_parses_with_12_loops() {
        let prog = parse(SOBEL_C).unwrap();
        assert_eq!(prog.loop_count, 12);
    }

    #[test]
    fn all_sources_typecheck() {
        for app in APPS {
            let prog = parse(source(app).unwrap()).unwrap();
            let errs = typecheck::check(&prog);
            assert!(errs.is_empty(), "{app}: {errs:?}");
        }
    }

    #[test]
    fn all_sources_execute() {
        use crate::minic::Interp;
        for app in APPS {
            let prog = parse(source(app).unwrap()).unwrap();
            let mut interp = Interp::new(&prog).unwrap();
            interp.call("main", &[]).unwrap_or_else(|e| {
                panic!("{app} failed to run: {e}");
            });
            // Every loop in the hot path must have been profiled.
            assert!(
                !interp.profile().loops.is_empty(),
                "{app}: no loops profiled"
            );
        }
    }

    #[test]
    fn tdfir_internal_verification_passes() {
        use crate::minic::Interp;
        let prog = parse(TDFIR_C).unwrap();
        let mut interp = Interp::new(&prog).unwrap();
        interp.call("main", &[]).unwrap();
        // The in-app spot check: bank output matches the naive reference.
        let maxerr = interp.global_scalar("maxerr").unwrap();
        assert!(maxerr < 1e-9, "tdfir self-check failed: maxerr={maxerr}");
        let energy = interp.global_scalar("out_energy").unwrap();
        assert!(energy > 0.0);
    }

    #[test]
    fn mriq_internal_verification_passes() {
        use crate::minic::Interp;
        let prog = parse(MRIQ_C).unwrap();
        let mut interp = Interp::new(&prog).unwrap();
        interp.call("main", &[]).unwrap();
        let maxerr = interp.global_scalar("maxerr").unwrap();
        assert!(maxerr < 1e-9, "mriq self-check failed: maxerr={maxerr}");
        let energy = interp.global_scalar("q_energy").unwrap();
        assert!(energy > 0.0);
    }

    #[test]
    fn unknown_app_is_none() {
        assert!(source("nope").is_none());
    }
}
