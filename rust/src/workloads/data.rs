//! Deterministic sample-data generators for the evaluated applications.
//!
//! The verification environment measures every offload pattern on the same
//! sample inputs (paper §4: performance is measured with "the sample
//! processing specified by the application"), so generation is seeded and
//! platform-independent (our PCG32, not libc rand).

use crate::runtime::artifacts::{MriqShape, TdfirShape};
use crate::util::rng::Pcg32;

/// Inputs for the TDFIR sample test (row-major flattened).
#[derive(Debug, Clone)]
pub struct TdfirInputs {
    pub xr: Vec<f32>,
    pub xi: Vec<f32>,
    pub hr: Vec<f32>,
    pub hi: Vec<f32>,
}

/// Inputs for the MRI-Q sample test.
#[derive(Debug, Clone)]
pub struct MriqInputs {
    pub kx: Vec<f32>,
    pub ky: Vec<f32>,
    pub kz: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub z: Vec<f32>,
    pub phir: Vec<f32>,
    pub phii: Vec<f32>,
}

fn uniform_vec(rng: &mut Pcg32, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.range_f32(lo, hi)).collect()
}

/// Generate TDFIR inputs: unit-ish signal, taps shaped like a windowed
/// band-pass so outputs stay O(1).
pub fn tdfir_inputs(shape: TdfirShape, seed: u64) -> TdfirInputs {
    let mut rng = Pcg32::new(seed, 0x7df1);
    let TdfirShape { m, n, k } = shape;
    let scale = 1.0 / (k as f32).sqrt();
    TdfirInputs {
        xr: uniform_vec(&mut rng, m * n, -1.0, 1.0),
        xi: uniform_vec(&mut rng, m * n, -1.0, 1.0),
        hr: uniform_vec(&mut rng, m * k, -scale, scale),
        hi: uniform_vec(&mut rng, m * k, -scale, scale),
    }
}

/// Generate MRI-Q inputs: trajectory and voxel coordinates in [-0.5, 0.5)
/// (normalized k-space units, like Parboil), unit-ish phase.
pub fn mriq_inputs(shape: MriqShape, seed: u64) -> MriqInputs {
    let mut rng = Pcg32::new(seed, 0x3219);
    let MriqShape { k, x } = shape;
    MriqInputs {
        kx: uniform_vec(&mut rng, k, -0.5, 0.5),
        ky: uniform_vec(&mut rng, k, -0.5, 0.5),
        kz: uniform_vec(&mut rng, k, -0.5, 0.5),
        x: uniform_vec(&mut rng, x, -0.5, 0.5),
        y: uniform_vec(&mut rng, x, -0.5, 0.5),
        z: uniform_vec(&mut rng, x, -0.5, 0.5),
        phir: uniform_vec(&mut rng, k, -1.0, 1.0),
        phii: uniform_vec(&mut rng, k, -1.0, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tdfir_inputs_deterministic() {
        let s = TdfirShape { m: 2, n: 16, k: 4 };
        let a = tdfir_inputs(s, 9);
        let b = tdfir_inputs(s, 9);
        assert_eq!(a.xr, b.xr);
        assert_eq!(a.hi, b.hi);
        let c = tdfir_inputs(s, 10);
        assert_ne!(a.xr, c.xr);
    }

    #[test]
    fn mriq_inputs_in_range() {
        let s = MriqShape { k: 32, x: 16 };
        let inp = mriq_inputs(s, 1);
        assert_eq!(inp.kx.len(), 32);
        assert_eq!(inp.x.len(), 16);
        assert!(inp.kx.iter().all(|&v| (-0.5..0.5).contains(&v)));
        assert!(inp.phir.iter().all(|&v| (-1.0..1.0).contains(&v)));
    }

    #[test]
    fn tdfir_tap_scale_bounded() {
        let s = TdfirShape { m: 1, n: 8, k: 64 };
        let inp = tdfir_inputs(s, 2);
        let bound = 1.0 / 8.0; // 1/sqrt(64)
        assert!(inp.hr.iter().all(|&v| v.abs() <= bound));
    }
}
