//! Rust reference implementations of the evaluated applications' numerics.
//!
//! Independent of both the JAX/Pallas path (python/compile/kernels/) and
//! the MiniC interpreter — a third implementation, so agreement between
//! any two is strong evidence of correctness. f64 accumulation to act as
//! the "more precise oracle" for the f32 kernels.

/// Complex FIR filter bank: `y[m][n] = Σ_j h[m][j] * x[m][n-j]`.
///
/// Inputs are row-major `[m, n]` / `[m, k]` flattened slices; returns
/// `(yr, yi)` of length `m*n`.
pub fn tdfir(
    xr: &[f32],
    xi: &[f32],
    hr: &[f32],
    hi: &[f32],
    m: usize,
    n: usize,
    k: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(xr.len(), m * n);
    assert_eq!(hr.len(), m * k);
    let mut yr = vec![0f32; m * n];
    let mut yi = vec![0f32; m * n];
    for row in 0..m {
        for out in 0..n {
            let mut acc_r = 0f64;
            let mut acc_i = 0f64;
            for j in 0..=out.min(k - 1) {
                let xv_r = xr[row * n + out - j] as f64;
                let xv_i = xi[row * n + out - j] as f64;
                let h_r = hr[row * k + j] as f64;
                let h_i = hi[row * k + j] as f64;
                acc_r += h_r * xv_r - h_i * xv_i;
                acc_i += h_r * xv_i + h_i * xv_r;
            }
            yr[row * n + out] = acc_r as f32;
            yi[row * n + out] = acc_i as f32;
        }
    }
    (yr, yi)
}

/// MRI-Q: `q[i] = Σ_k |phi[k]|² · exp(i·2π·(kx·x + ky·y + kz·z))`.
///
/// Returns `(qr, qi)` of length `x.len()`.
#[allow(clippy::too_many_arguments)]
pub fn mriq(
    kx: &[f32],
    ky: &[f32],
    kz: &[f32],
    x: &[f32],
    y: &[f32],
    z: &[f32],
    phir: &[f32],
    phii: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let kd = kx.len();
    assert_eq!(ky.len(), kd);
    assert_eq!(kz.len(), kd);
    assert_eq!(phir.len(), kd);
    assert_eq!(phii.len(), kd);
    let xd = x.len();
    assert_eq!(y.len(), xd);
    assert_eq!(z.len(), xd);

    const TWO_PI: f64 = 6.283185307179586476925286766559;
    let phimag: Vec<f64> = (0..kd)
        .map(|j| {
            let r = phir[j] as f64;
            let i = phii[j] as f64;
            r * r + i * i
        })
        .collect();

    let mut qr = vec![0f32; xd];
    let mut qi = vec![0f32; xd];
    for i in 0..xd {
        let (xi_, yi_, zi_) = (x[i] as f64, y[i] as f64, z[i] as f64);
        let mut acc_r = 0f64;
        let mut acc_i = 0f64;
        for j in 0..kd {
            let arg = TWO_PI
                * (kx[j] as f64 * xi_ + ky[j] as f64 * yi_
                    + kz[j] as f64 * zi_);
            // Compute in f32 precision for the trig argument to mirror the
            // kernel (XLA evaluates cos/sin on the f32 value); accumulate
            // in f64.
            let arg32 = arg as f32;
            acc_r += phimag[j] * (arg32.cos() as f64);
            acc_i += phimag[j] * (arg32.sin() as f64);
        }
        qr[i] = acc_r as f32;
        qi[i] = acc_i as f32;
    }
    (qr, qi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tdfir_impulse_recovers_taps() {
        let (m, n, k) = (1, 8, 3);
        let mut xr = vec![0f32; n];
        xr[0] = 1.0;
        let xi = vec![0f32; n];
        let hr = vec![0.5, -1.0, 2.0];
        let hi = vec![1.0, 0.25, -0.5];
        let (yr, yi) = tdfir(&xr, &xi, &hr, &hi, m, n, k);
        assert_eq!(&yr[..k], &hr[..]);
        assert_eq!(&yi[..k], &hi[..]);
        assert!(yr[k..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tdfir_single_tap_scales() {
        let (m, n, k) = (1, 4, 1);
        let xr = vec![1.0, 2.0, 3.0, 4.0];
        let xi = vec![0.5, 0.5, 0.5, 0.5];
        let hr = vec![2.0];
        let hi = vec![1.0];
        let (yr, yi) = tdfir(&xr, &xi, &hr, &hi, m, n, k);
        for i in 0..n {
            assert!((yr[i] - (2.0 * xr[i] - 1.0 * xi[i])).abs() < 1e-6);
            assert!((yi[i] - (2.0 * xi[i] + 1.0 * xr[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn mriq_zero_phase_is_zero() {
        let kd = 4;
        let xd = 3;
        let zeros_k = vec![0f32; kd];
        let ones_k = vec![1f32; kd];
        let coords = vec![0.3f32, -0.2, 0.9];
        let (qr, qi) = mriq(
            &ones_k, &ones_k, &ones_k, &coords, &coords, &coords, &zeros_k,
            &zeros_k,
        );
        assert!(qr.iter().all(|&v| v == 0.0));
        assert!(qi.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mriq_origin_voxel_sums_phimag() {
        let kd = 5;
        let kx: Vec<f32> = (0..kd).map(|i| i as f32 * 0.17).collect();
        let phir = vec![1.0f32, 2.0, 0.5, -1.0, 0.25];
        let phii = vec![0.5f32, -0.5, 1.5, 0.0, 2.0];
        let zero = vec![0f32; 1];
        let (qr, qi) =
            mriq(&kx, &kx, &kx, &zero, &zero, &zero, &phir, &phii);
        let expect: f32 = phir
            .iter()
            .zip(&phii)
            .map(|(r, i)| r * r + i * i)
            .sum();
        assert!((qr[0] - expect).abs() < 1e-4, "{} vs {expect}", qr[0]);
        assert!(qi[0].abs() < 1e-5);
    }
}
