//! Evaluated applications: embedded MiniC sources, Rust reference
//! numerics, and deterministic sample-data generators.
//!
//! Three bundled workloads, each exercising a different routing story:
//! `tdfir` (HPEC complex FIR bank — deep MAC pipelines, the FPGA's
//! home turf), `mriq` (MRI Q-matrix — trig-dense and massively
//! parallel, the GPU's), and `sobel` (3x3 gradient stencil —
//! memory-heavy with light per-pixel work, the many-core's).
//!
//! ```
//! use fpga_offload::minic::parse;
//! use fpga_offload::workloads;
//!
//! assert_eq!(workloads::APPS, ["tdfir", "mriq", "sobel"]);
//! for app in workloads::APPS {
//!     let src = workloads::source(app).expect("bundled");
//!     assert!(parse(src).is_ok(), "{app} must stay parseable");
//! }
//! assert!(workloads::source("ghost").is_none());
//! ```

pub mod data;
pub mod reference;
pub mod sources;

pub use sources::{source, APPS, MRIQ_C, SOBEL_C, TDFIR_C};
