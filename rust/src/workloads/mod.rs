//! Evaluated applications: embedded MiniC sources, Rust reference
//! numerics, and deterministic sample-data generators.

pub mod data;
pub mod reference;
pub mod sources;

pub use sources::{source, APPS, MRIQ_C, SOBEL_C, TDFIR_C};
