/* HPEC Challenge TDFIR — time-domain FIR filter bank (paper §5.1.2).
 *
 * M complex FIR filters of K taps each run over an N-sample complex
 * input, repeated REP times (the HPEC harness repeats the kernel for
 * timing).  The hot nest is fir_all(): loops L12 (repetition), L13
 * (filter bank), L14 (output sample), L15 (tap MAC).  A while-based
 * spot check recomputes CHK banks naively and folds the worst absolute
 * difference into `maxerr`; post-processing passes (power, corner
 * turn, peaks, histogram, checksums) model the rest of the pulse-
 * compression pipeline and stay on the CPU.
 *
 * 36 loop statements (L0..L35), ids in source order.
 */
#include <math.h>

#define M 8
#define N 1024
#define K 16
#define K1 15
#define REP 2
#define NIN 1040
#define CHK 3
#define ND 256
#define NB 16

float xr[NIN];
float xi[NIN];
float scratch[NIN];
float hr[M][K];
float hi[M][K];
float hrevr[M][K];
float hrevi[M][K];
float gain[M];
float outr[M][N];
float outi[M][N];
float mag[M][N];
float stager[N][M];
float stagei[N][M];
float bankpeak[M];
float banksum[M];
float dec[ND];
float hist[NB];
float maxerr;
float out_energy;
float chk;
float dsum;

/* Deterministic pseudo-random pulse (no libc rand in MiniC). */
void gen_input() {
    for (int i = 0; i < NIN; i++) {                      /* L0 */
        xr[i] = (i % 37) * 0.053 - 0.9;
        xi[i] = (i % 29) * 0.067 - 0.95;
    }
}

void gen_coef() {
    for (int m = 0; m < M; m++) {                        /* L1 */
        for (int k = 0; k < K; k++) {                    /* L2 */
            hr[m][k] = (m * 13 + k * 5) % 23 * 0.041 - 0.45;
            hi[m][k] = (m * 7 + k * 11) % 19 * 0.049 - 0.43;
        }
    }
}

void clear_out() {
    for (int m = 0; m < M; m++) {                        /* L3 */
        for (int n = 0; n < N; n++) {                    /* L4 */
            outr[m][n] = 0.0;
            outi[m][n] = 0.0;
        }
    }
}

/* Raised-cosine-ish taper, arithmetic only. */
void taper_input() {
    for (int i = 0; i < NIN; i++) {                      /* L5 */
        xr[i] = xr[i] * (0.9 + (i % 11) * 0.01);
    }
    for (int i = 0; i < NIN; i++) {                      /* L6 */
        xi[i] = xi[i] * (0.9 + (i % 13) * 0.008);
    }
}

/* Normalize each filter to roughly unit energy. */
void norm_coef() {
    for (int m = 0; m < M; m++) {                        /* L7 */
        float g = 0.0;
        for (int k = 0; k < K; k++) {                    /* L8 */
            g += hr[m][k] * hr[m][k] + hi[m][k] * hi[m][k];
        }
        gain[m] = 1.0 / (sqrt(g) + 1.0);
        for (int k = 0; k < K; k++) {                    /* L9 */
            hr[m][k] = hr[m][k] * gain[m];
            hi[m][k] = hi[m][k] * gain[m];
        }
    }
}

/* Tap reversal: convolution reads taps back to front. */
void reverse_coef() {
    for (int m = 0; m < M; m++) {                        /* L10 */
        for (int k = 0; k < K; k++) {                    /* L11 */
            hrevr[m][k] = hr[m][K1 - k];
            hrevi[m][k] = hi[m][K1 - k];
        }
    }
}

/* The hot nest: complex FIR bank, repeated REP times. */
void fir_all() {
    for (int r = 0; r < REP; r++) {                      /* L12 */
        for (int m = 0; m < M; m++) {                    /* L13 */
            for (int n = 0; n < N; n++) {                /* L14 */
                float accr = 0.0;
                float acci = 0.0;
                for (int k = 0; k < K; k++) {            /* L15 */
                    accr += hrevr[m][k] * xr[n + k] - hrevi[m][k] * xi[n + k];
                    acci += hrevr[m][k] * xi[n + k] + hrevi[m][k] * xr[n + k];
                }
                outr[m][n] = accr;
                outi[m][n] = acci;
            }
        }
    }
}

/* Naive recomputation of the first CHK banks (data-dependent control,
 * so this stays on the CPU — while loops are not offload candidates). */
void check_ref() {
    int cm = 0;
    while (cm < CHK) {                                   /* L16 */
        int cn = 0;
        while (cn < N) {                                 /* L17 */
            float rr = 0.0;
            float ri = 0.0;
            int ck = 0;
            while (ck < K) {                             /* L18 */
                rr += hr[cm][K1 - ck] * xr[cn + ck] - hi[cm][K1 - ck] * xi[cn + ck];
                ri += hr[cm][K1 - ck] * xi[cn + ck] + hi[cm][K1 - ck] * xr[cn + ck];
                ck++;
            }
            maxerr = fmax(maxerr, fabs(outr[cm][cn] - rr));
            maxerr = fmax(maxerr, fabs(outi[cm][cn] - ri));
            cn++;
        }
        cm++;
    }
}

void energy() {
    for (int m = 0; m < M; m++) {                        /* L19 */
        for (int n = 0; n < N; n++) {                    /* L20 */
            out_energy += outr[m][n] * outr[m][n] + outi[m][n] * outi[m][n];
        }
    }
}

/* Power spectrum per bank. */
void power_grid() {
    for (int m = 0; m < M; m++) {                        /* L21 */
        for (int n = 0; n < N; n++) {                    /* L22 */
            mag[m][n] = outr[m][n] * outr[m][n] + outi[m][n] * outi[m][n];
        }
    }
}

/* Corner turn: sample-major staging for the next pipeline stage. */
void corner_turn() {
    for (int m = 0; m < M; m++) {                        /* L23 */
        for (int n = 0; n < N; n++) {                    /* L24 */
            stager[n][m] = outr[m][n];
            stagei[n][m] = outi[m][n];
        }
    }
}

void peaks() {
    for (int m = 0; m < M; m++) {                        /* L25 */
        for (int n = 0; n < N; n++) {                    /* L26 */
            bankpeak[m] = fmax(bankpeak[m], mag[m][n]);
        }
    }
    for (int m = 0; m < M; m++) {                        /* L27 */
        for (int n = 0; n < N; n++) {                    /* L28 */
            banksum[m] += mag[m][n];
        }
    }
}

void decimate() {
    for (int d = 0; d < ND; d++) {                       /* L29 */
        dec[d] = stager[d * 4][0];
    }
}

void histogram() {
    for (int m = 0; m < M; m++) {                        /* L30 */
        for (int n = 0; n < N; n++) {                    /* L31 */
            int b = (int) fmin(mag[m][n] * 2.0, 15.0);
            hist[b] += 1.0;
        }
    }
}

void checksum() {
    for (int n = 0; n < N; n++) {                        /* L32 */
        for (int m = 0; m < M; m++) {                    /* L33 */
            chk += stager[n][m] - stagei[n][m];
        }
    }
    for (int i = 0; i < NIN; i++) {                      /* L34 */
        scratch[i] = xr[i] + xi[i];
    }
    for (int d = 0; d < ND; d++) {                       /* L35 */
        dsum += dec[d];
    }
}

int main() {
    gen_input();
    gen_coef();
    clear_out();
    taper_input();
    norm_coef();
    reverse_coef();
    fir_all();
    check_ref();
    energy();
    power_grid();
    corner_turn();
    peaks();
    decimate();
    histogram();
    checksum();
    printf("tdfir maxerr=%f energy=%f\n", maxerr, out_energy);
    return 0;
}
