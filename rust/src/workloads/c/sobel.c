/* Sobel edge detection — the extra IoT-imaging workload.
 *
 * Synthetic H×W frame -> 3x3 box blur -> Sobel gradient magnitude
 * (the hot nest, L4/L5: 3x3 stencil with a sqrt per pixel) ->
 * thresholded edge count, row sums, and frame statistics.
 *
 * 12 loop statements (L0..L11), ids in source order.
 */
#include <math.h>

#define H 96
#define W 96
#define H1 95
#define W1 95

float img[H][W];
float tmp[H][W];
float gmag[H][W];
float rowsum[H];
float gsum;
float ecount;
float pmax;

void gen_frame() {
    for (int y = 0; y < H; y++) {                        /* L0 */
        for (int x = 0; x < W; x++) {                    /* L1 */
            img[y][x] = (y * 13 + x * 7) % 31 * 0.08 - 1.2;
        }
    }
}

void blur() {
    for (int y = 1; y < H1; y++) {                       /* L2 */
        for (int x = 1; x < W1; x++) {                   /* L3 */
            tmp[y][x] = (img[y][x] * 4.0 + img[y - 1][x] + img[y + 1][x]
                + img[y][x - 1] + img[y][x + 1]) * 0.125;
        }
    }
}

/* The hot nest: Sobel gradient magnitude. */
void gradient() {
    for (int y = 1; y < H1; y++) {                       /* L4 */
        for (int x = 1; x < W1; x++) {                   /* L5 */
            float gx = (tmp[y - 1][x + 1] + tmp[y][x + 1] * 2.0 + tmp[y + 1][x + 1])
                - (tmp[y - 1][x - 1] + tmp[y][x - 1] * 2.0 + tmp[y + 1][x - 1]);
            float gy = (tmp[y + 1][x - 1] + tmp[y + 1][x] * 2.0 + tmp[y + 1][x + 1])
                - (tmp[y - 1][x - 1] + tmp[y - 1][x] * 2.0 + tmp[y - 1][x + 1]);
            gmag[y][x] = sqrt(gx * gx + gy * gy);
        }
    }
}

void threshold() {
    for (int y = 0; y < H; y++) {                        /* L6 */
        for (int x = 0; x < W; x++) {                    /* L7 */
            if (gmag[y][x] > 1.5) {
                ecount += 1.0;
            }
        }
    }
}

void row_sums() {
    for (int y = 0; y < H; y++) {                        /* L8 */
        for (int x = 0; x < W; x++) {                    /* L9 */
            rowsum[y] += gmag[y][x];
        }
    }
}

void stats() {
    for (int y = 0; y < H; y++) {                        /* L10 */
        gsum += rowsum[y];
    }
    for (int y = 0; y < H; y++) {                        /* L11 */
        pmax = fmax(pmax, rowsum[y]);
    }
}

int main() {
    gen_frame();
    blur();
    gradient();
    threshold();
    row_sums();
    stats();
    printf("sobel edges=%f gsum=%f\n", ecount, gsum);
    return 0;
}
