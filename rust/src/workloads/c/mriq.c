/* Parboil MRI-Q — non-Cartesian MRI reconstruction, Q matrix
 * computation (paper §5.1.2).
 *
 * For every voxel the Q value accumulates phiMag[k] * exp(i*phase)
 * over the k-space trajectory; the phase is the dot product of the
 * k-space vector and the voxel position.  The hot nest is compute_q():
 * L4 (repetition), L5 (voxel), L6 (k-space MAC with sin/cos).  A
 * while-based naive recheck of the first CHKV voxels folds the worst
 * difference into `maxerr`; magnitude/histogram/decimation passes model
 * the rest of the reconstruction chain.
 *
 * 16 loop statements (L0..L15), ids in source order.
 */
#include <math.h>

#define KS 32
#define X 1536
#define X1 1535
#define QREP 2
#define CHKV 320
#define DEC 128
#define NB 16

float kx[KS];
float ky[KS];
float kz[KS];
float phiR[KS];
float phiI[KS];
float phiMag[KS];
float x[X];
float y[X];
float z[X];
float qr[X];
float qi[X];
float qmag[X];
float qsm[X];
float qdec[DEC];
float hcount[NB];
float maxerr;
float q_energy;
float qpeak;
float qsum;

/* Deterministic k-space trajectory and coil phases. */
void gen_kspace() {
    for (int k = 0; k < KS; k++) {                       /* L0 */
        kx[k] = (k % 7) * 0.11 - 0.33;
        ky[k] = (k % 5) * 0.17 - 0.34;
        kz[k] = (k % 11) * 0.06 - 0.3;
        phiR[k] = (k % 13) * 0.07 - 0.42;
        phiI[k] = (k % 3) * 0.21 - 0.2;
    }
}

void gen_phimag() {
    for (int k = 0; k < KS; k++) {                       /* L1 */
        phiMag[k] = phiR[k] * phiR[k] + phiI[k] * phiI[k];
    }
}

void gen_voxels() {
    for (int i = 0; i < X; i++) {                        /* L2 */
        x[i] = (i % 53) * 0.021 - 0.55;
        y[i] = (i % 47) * 0.023 - 0.52;
        z[i] = (i % 43) * 0.026 - 0.56;
    }
}

void clear_q() {
    for (int i = 0; i < X; i++) {                        /* L3 */
        qr[i] = 0.0;
        qi[i] = 0.0;
    }
}

/* The hot nest: Q accumulation over the k-space trajectory. */
void compute_q() {
    for (int r = 0; r < QREP; r++) {                     /* L4 */
        for (int i = 0; i < X; i++) {                    /* L5 */
            float xv = x[i];
            float yv = y[i];
            float zv = z[i];
            float sr = 0.0;
            float si = 0.0;
            for (int k = 0; k < KS; k++) {               /* L6 */
                float ph = kx[k] * xv + ky[k] * yv + kz[k] * zv;
                float cs = cos(ph);
                float sn = sin(ph);
                sr += phiMag[k] * cs;
                si += phiMag[k] * sn;
            }
            qr[i] = sr;
            qi[i] = si;
        }
    }
}

/* Naive recheck of the first CHKV voxels (data-dependent control keeps
 * this on the CPU — while loops are not offload candidates). */
void check_ref() {
    int ci = 0;
    while (ci < CHKV) {                                  /* L7 */
        float rr = 0.0;
        float ri = 0.0;
        int ck = 0;
        while (ck < KS) {                                /* L8 */
            float ph = kx[ck] * x[ci] + ky[ck] * y[ci] + kz[ck] * z[ci];
            float cs = cos(ph);
            float sn = sin(ph);
            rr += phiMag[ck] * cs;
            ri += phiMag[ck] * sn;
            ck++;
        }
        maxerr = fmax(maxerr, fabs(qr[ci] - rr));
        maxerr = fmax(maxerr, fabs(qi[ci] - ri));
        ci++;
    }
}

void energy() {
    for (int i = 0; i < X; i++) {                        /* L9 */
        q_energy += qr[i] * qr[i] + qi[i] * qi[i];
    }
}

void magnitude() {
    for (int i = 0; i < X; i++) {                        /* L10 */
        qmag[i] = sqrt(qr[i] * qr[i] + qi[i] * qi[i]);
    }
}

void peak() {
    for (int i = 0; i < X; i++) {                        /* L11 */
        qpeak = fmax(qpeak, qmag[i]);
    }
}

void smooth() {
    for (int i = 1; i < X1; i++) {                       /* L12 */
        qsm[i] = (qmag[i - 1] + qmag[i] + qmag[i + 1]) * 0.333333;
    }
}

void histogram() {
    for (int i = 0; i < X; i++) {                        /* L13 */
        int b = (int) fmin(qsm[i] * 4.0, 15.0);
        hcount[b] += 1.0;
    }
}

void decimate() {
    for (int d = 0; d < DEC; d++) {                      /* L14 */
        qdec[d] = qsm[d * 8];
    }
}

void checksum() {
    for (int d = 0; d < DEC; d++) {                      /* L15 */
        qsum += qdec[d];
    }
}

int main() {
    gen_kspace();
    gen_phimag();
    gen_voxels();
    clear_q();
    compute_q();
    check_ref();
    energy();
    magnitude();
    peak();
    smooth();
    histogram();
    decimate();
    checksum();
    printf("mriq maxerr=%f energy=%f\n", maxerr, q_energy);
    return 0;
}
