//! Cost-aware eviction policy for the pattern store.
//!
//! When a capacity is configured and the store grows past it, records
//! must go — but unlike a generic LRU, pattern records have wildly
//! different replacement costs: `automation_hours` is the solve time
//! the paper's funnel + verification sweep took to discover the plan, a
//! stand-in for a multi-hour HLS build. Evicting a 12-hour plan to keep
//! a 4-minute one is a bad trade even if the 12-hour plan is older.
//!
//! The policy: each record gets a *keep score* of stored solve cost
//! discounted by staleness — `automation_hours / (1 + age_hours)` — and
//! the lowest score is evicted first. Stale records decay toward
//! eviction (they were going to be re-searched under the age policy
//! anyway), expensive records resist it, and unstamped records (no
//! `stored_at`, infinitely old under every age policy) always go first.
//! Ties break on the app name so concurrent runs evict deterministically.

use crate::envadapt::patterndb::StoredPattern;

/// Keep score at `now`. Higher = more worth keeping.
pub(crate) fn keep_score(rec: &StoredPattern, now: u64) -> f64 {
    match rec.age_secs(now) {
        // Unstamped: infinitely stale, first out the door.
        None => -1.0,
        Some(age) => {
            let age_hours = age as f64 / 3600.0;
            rec.automation_hours.max(0.0) / (1.0 + age_hours)
        }
    }
}

/// Pick the `excess` cheapest-to-recompute victims from `candidates`,
/// never choosing `protect` (the app whose store triggered the
/// eviction — evicting what was just written would thrash).
pub(crate) fn choose_victims(
    candidates: &[StoredPattern],
    excess: usize,
    protect: &str,
    now: u64,
) -> Vec<String> {
    let mut scored: Vec<(f64, &str)> = candidates
        .iter()
        .filter(|r| r.app != protect)
        .map(|r| (keep_score(r, now), r.app.as_str()))
        .collect();
    scored.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.1.cmp(b.1))
    });
    scored
        .into_iter()
        .take(excess)
        .map(|(_, app)| app.to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(app: &str, hours: f64, stored_at: Option<u64>) -> StoredPattern {
        StoredPattern {
            app: app.to_string(),
            source_hash: None,
            backend: None,
            entry: None,
            device: None,
            config_fp: None,
            catalog_fp: None,
            stored_at,
            best_pattern: vec![],
            blocks: 0,
            speedup: 1.0,
            automation_hours: hours,
            verified: None,
        }
    }

    #[test]
    fn cheap_and_stale_go_before_expensive_and_fresh() {
        let now = 1_000_000;
        let candidates = vec![
            rec("expensive-fresh", 12.0, Some(now - 60)),
            rec("cheap-fresh", 0.1, Some(now - 60)),
            rec("expensive-stale", 12.0, Some(now - 14 * 86_400)),
            rec("cheap-stale", 0.1, Some(now - 14 * 86_400)),
        ];
        let victims = choose_victims(&candidates, 2, "none", now);
        assert_eq!(victims, vec!["cheap-stale", "expensive-stale"]);
        // Two weeks of staleness discounts a 12-hour plan below a fresh
        // 6-minute one (12/337 < 0.1/1): age wins the next slot.
        let three = choose_victims(&candidates, 3, "none", now);
        assert_eq!(three[2], "cheap-fresh");
    }

    #[test]
    fn unstamped_records_evict_first_and_protect_is_never_chosen() {
        let now = 1_000_000;
        let candidates = vec![
            rec("unstamped", 100.0, None),
            rec("fresh", 0.01, Some(now)),
        ];
        assert_eq!(
            choose_victims(&candidates, 1, "none", now),
            vec!["unstamped"]
        );
        assert_eq!(
            choose_victims(&candidates, 2, "unstamped", now),
            vec!["fresh"]
        );
    }

    #[test]
    fn ties_break_deterministically_by_app_name() {
        let now = 500;
        let candidates = vec![
            rec("b", 1.0, Some(now)),
            rec("a", 1.0, Some(now)),
            rec("c", 1.0, Some(now)),
        ];
        assert_eq!(choose_victims(&candidates, 2, "none", now), vec!["a", "b"]);
    }
}
