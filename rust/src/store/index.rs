//! Shard routing and the process-wide open-store registry.
//!
//! ## Routing
//!
//! Records are keyed by app name (the unit every lookup, refresh, and
//! tombstone addresses — a [`crate::envadapt::ReuseKey`] is matched
//! *within* the app's record), so the app name is what routes to a
//! shard: FNV-1a of the name, mod 16. The same hash family fingerprints
//! sources and frames log records, so the whole store speaks one hash.
//!
//! 16 shards is deliberate overprovisioning for the service tier's
//! worker pools (2–16 workers): with independent writer mutexes per
//! shard, the probability that two concurrent cold solves serialize on
//! the same lock stays low, and a shard log at 10k records holds ~625
//! records — a sub-millisecond replay.
//!
//! ## Registry
//!
//! Opening the same directory twice in one process must yield the
//! *same* store: the service's `PatternIndex` and a pipeline's
//! `PatternDb` write through one set of shard locks and one in-memory
//! index (this is also what makes warm opens O(1) — the replay already
//! happened). The registry maps the canonicalized directory to a
//! [`Weak`] handle: when the last `Arc` drops, the entry dies, and the
//! next open replays from disk — which is exactly what crash-recovery
//! tests (drop, mangle bytes, reopen) need.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use super::PatternStore;

/// Number of shards per store directory. Baked into the on-disk layout
/// (`shard-00.log` … `shard-15.log`); changing it is a migration.
pub const SHARD_COUNT: usize = 16;

/// Which shard an app's records live in.
pub(crate) fn shard_of(app: &str) -> usize {
    (super::log::fnv1a(app.as_bytes()) % SHARD_COUNT as u64) as usize
}

/// Log file name for a shard slot.
pub(crate) fn shard_file(slot: usize) -> String {
    format!("shard-{slot:02}.log")
}

type Registry = Mutex<HashMap<PathBuf, Weak<PatternStore>>>;

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Stable per-directory key. The directory exists by the time this is
/// called (open creates it), so canonicalization only fails on exotic
/// filesystems — fall back to the raw path rather than erroring.
fn registry_key(dir: &Path) -> PathBuf {
    dir.canonicalize().unwrap_or_else(|_| dir.to_path_buf())
}

/// A live store already open on `dir`, if any.
pub(crate) fn lookup(dir: &Path) -> Option<Arc<PatternStore>> {
    let key = registry_key(dir);
    let guard = registry().lock().unwrap_or_else(|p| p.into_inner());
    guard.get(&key).and_then(Weak::upgrade)
}

/// Publish a freshly opened store (and sweep dead entries so the map
/// doesn't accumulate one tombstone per temp dir ever opened).
pub(crate) fn publish(dir: &Path, store: &Arc<PatternStore>) {
    let key = registry_key(dir);
    let mut guard = registry().lock().unwrap_or_else(|p| p.into_inner());
    guard.retain(|_, w| w.strong_count() > 0);
    guard.insert(key, Arc::downgrade(store));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for app in ["tdfir", "mriq", "sobel", "", "a", "Ω"] {
            let s = shard_of(app);
            assert!(s < SHARD_COUNT);
            assert_eq!(s, shard_of(app));
        }
    }

    #[test]
    fn shard_files_are_zero_padded_and_unique() {
        let names: std::collections::BTreeSet<String> =
            (0..SHARD_COUNT).map(shard_file).collect();
        assert_eq!(names.len(), SHARD_COUNT);
        assert!(names.contains("shard-00.log"));
        assert!(names.contains("shard-15.log"));
    }
}
