//! One shard of the pattern store: an append-only log file plus the
//! in-memory index replayed from it.
//!
//! Concurrency contract (the whole point of sharding):
//!
//! * Every *mutation* — append, tombstone, restamp, compaction, refresh
//!   — first takes this shard's `writer` mutex, does its log I/O, then
//!   briefly takes the index write lock to publish the result. Writers
//!   on different shards never contend.
//! * Every *read* takes only the index read lock and clones an entry.
//!   The hit path therefore never waits on log I/O, only on the
//!   microseconds-long publish of a concurrent writer on the *same*
//!   shard — cold solves on other shards are invisible to it.
//!
//! Records in the log are whole-JSON payloads (the same schema as the
//! legacy one-file-per-app layout, so migration is a byte-preserving
//! append). Later records for an app supersede earlier ones; a
//! `{"tombstone": app}` payload deletes. Superseded and tombstone
//! records are *dead* — still in the file, invisible to readers — and
//! the dead count drives compaction.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard, RwLock};

use anyhow::Result;

use crate::envadapt::patterndb::StoredPattern;
use crate::util::json::Json;

use super::log::{self, Recovery};
use super::stats::StoreStats;

/// A live record: the parsed summary the hit path matches against plus
/// the full JSON the `load` surface returns.
#[derive(Debug, Clone)]
pub(crate) struct Entry {
    pub rec: StoredPattern,
    pub json: Json,
}

/// One decoded log payload.
pub(crate) enum Payload {
    Record(Entry),
    Tombstone(String),
}

/// Decode a log payload. `None` means the payload checksummed fine but
/// is not a record this version understands — counted by callers, never
/// fatal.
pub(crate) fn decode(bytes: &[u8]) -> Option<Payload> {
    let text = std::str::from_utf8(bytes).ok()?;
    let json = Json::parse(text).ok()?;
    if let Some(app) = json.get(&["tombstone"]).and_then(Json::as_str) {
        return Some(Payload::Tombstone(app.to_string()));
    }
    let rec = StoredPattern::from_json(&json, None)?;
    Some(Payload::Record(Entry { rec, json }))
}

fn encode_tombstone(app: &str) -> Vec<u8> {
    Json::obj(vec![("tombstone", Json::Str(app.to_string()))])
        .pretty()
        .into_bytes()
}

/// Log bookkeeping, guarded by the writer mutex.
#[derive(Debug, Default)]
struct Bookkeeping {
    /// Records currently framed in the log file (live + dead).
    total: usize,
    /// Superseded records + tombstones — reclaimable by compaction.
    dead: usize,
}

/// Whether a keyed append survived the freshness rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum AppendOutcome {
    Stored,
    /// A fresher record (newer `stored_at`) was already live; the write
    /// was dropped, exactly as the flat-file rename rule dropped it.
    DroppedStale,
}

#[derive(Debug)]
pub(crate) struct Shard {
    path: PathBuf,
    writer: Mutex<Bookkeeping>,
    index: RwLock<HashMap<String, Entry>>,
}

impl Shard {
    /// Replay the log at `path` (repairing torn/corrupt damage per
    /// [`log::replay`]) and build the in-memory index.
    pub fn open(path: &Path, stats: &StoreStats) -> Result<Shard> {
        let (payloads, recovery) = log::replay(path)?;
        note_recovery(&recovery, stats);
        let (index, bk) = fold(&payloads);
        Ok(Shard {
            path: path.to_path_buf(),
            writer: Mutex::new(bk),
            index: RwLock::new(index),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn lock_writer(&self) -> MutexGuard<'_, Bookkeeping> {
        self.writer.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn read_index(
        &self,
    ) -> std::sync::RwLockReadGuard<'_, HashMap<String, Entry>> {
        self.index.read().unwrap_or_else(|p| p.into_inner())
    }

    fn write_index(
        &self,
    ) -> std::sync::RwLockWriteGuard<'_, HashMap<String, Entry>> {
        self.index.write().unwrap_or_else(|p| p.into_inner())
    }

    /// Live record for an app (read lock + clone; no I/O).
    pub fn get(&self, app: &str) -> Option<Entry> {
        self.read_index().get(app).cloned()
    }

    /// All live entries (unordered).
    pub fn entries(&self) -> Vec<Entry> {
        self.read_index().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.read_index().len()
    }

    /// Dead records currently reclaimable by compaction.
    pub fn dead(&self) -> usize {
        self.lock_writer().dead
    }

    /// Append a record. With `enforce_freshness` (every keyed write) an
    /// incoming stamp older than the live record's is dropped: when two
    /// workers race, the freshest solve survives, not the last rename.
    pub fn store(
        &self,
        entry: Entry,
        enforce_freshness: bool,
        stats: &StoreStats,
    ) -> Result<AppendOutcome> {
        let app = entry.rec.app.clone();
        let mut bk = self.lock_writer();
        if enforce_freshness {
            if let Some(live) = self.read_index().get(&app) {
                if live.rec.stored_at > entry.rec.stored_at {
                    stats.note_stale_write();
                    return Ok(AppendOutcome::DroppedStale);
                }
            }
        }
        log::append(&self.path, entry.json.pretty().as_bytes())?;
        stats.note_append();
        let replaced = self.write_index().insert(app, entry).is_some();
        bk.total += 1;
        if replaced {
            bk.dead += 1;
        }
        Ok(AppendOutcome::Stored)
    }

    /// Tombstone an app (eviction, operator delete). Returns whether a
    /// live record was actually removed.
    pub fn remove(&self, app: &str, stats: &StoreStats) -> Result<bool> {
        let mut bk = self.lock_writer();
        if !self.read_index().contains_key(app) {
            return Ok(false);
        }
        log::append(&self.path, &encode_tombstone(app))?;
        stats.note_append();
        self.write_index().remove(app);
        bk.total += 1;
        // The superseded record *and* the tombstone itself are dead.
        bk.dead += 2;
        Ok(true)
    }

    /// Rewrite an app's live record with a new `stored_at` stamp — the
    /// seam age-policy tests use instead of editing files by hand.
    pub fn restamp(
        &self,
        app: &str,
        stamp: u64,
        stats: &StoreStats,
    ) -> Result<bool> {
        let mut bk = self.lock_writer();
        let Some(mut entry) = self.read_index().get(app).cloned() else {
            return Ok(false);
        };
        entry.rec.stored_at = Some(stamp);
        if let Json::Obj(map) = &mut entry.json {
            map.insert(
                "stored_at".to_string(),
                Json::Str(format!("{stamp}")),
            );
        }
        log::append(&self.path, entry.json.pretty().as_bytes())?;
        stats.note_append();
        self.write_index().insert(app.to_string(), entry);
        bk.total += 1;
        bk.dead += 1;
        Ok(true)
    }

    /// Whether the dead-record load warrants a compaction. Checked by
    /// the store *after* a mutation returns (never inside one — the
    /// writer mutex is not reentrant).
    pub fn wants_compaction(&self, min_dead: usize, ratio: f64) -> bool {
        let bk = self.lock_writer();
        bk.dead >= min_dead
            && bk.total > 0
            && (bk.dead as f64) >= ratio * (bk.total as f64)
    }

    /// Rewrite the log with only the live records (atomic replace).
    /// Returns the number of dead records reclaimed.
    pub fn compact(&self, stats: &StoreStats) -> Result<usize> {
        let mut bk = self.lock_writer();
        let reclaimed = bk.dead;
        let mut live: Vec<(String, String)> = self
            .read_index()
            .iter()
            .map(|(app, e)| (app.clone(), e.json.pretty()))
            .collect();
        // Deterministic log order after compaction.
        live.sort_by(|a, b| a.0.cmp(&b.0));
        let payloads: Vec<&[u8]> =
            live.iter().map(|(_, j)| j.as_bytes()).collect();
        log::write_atomic(&self.path, &payloads)?;
        bk.total = live.len();
        bk.dead = 0;
        stats.note_compaction();
        Ok(reclaimed)
    }

    /// Re-read *one app's* entry from the log on disk (the satellite-1
    /// refresh semantics: an external process may have appended; sync
    /// just the affected entry instead of rebuilding every app). Runs
    /// under the writer mutex so it cannot interleave with in-process
    /// writers, and publishes the entry in one index-write — a
    /// concurrent hit sees either the old record or the new one, never
    /// a half-written state.
    pub fn refresh_app(
        &self,
        app: &str,
        stats: &StoreStats,
    ) -> Result<()> {
        let mut bk = self.lock_writer();
        let (payloads, recovery) = log::replay(&self.path)?;
        note_recovery(&recovery, stats);
        // Latest on-disk verdict for this app only.
        let mut latest: Option<Entry> = None;
        let total = payloads.len();
        let mut live_apps: HashMap<&str, bool> = HashMap::new();
        let decoded: Vec<Payload> =
            payloads.iter().filter_map(|p| decode(p)).collect();
        for payload in &decoded {
            match payload {
                Payload::Record(e) => {
                    if e.rec.app == app {
                        latest = Some(e.clone());
                    }
                    live_apps.insert(e.rec.app.as_str(), true);
                }
                Payload::Tombstone(t) => {
                    if t == app {
                        latest = None;
                    }
                    live_apps.insert(t.as_str(), false);
                }
            }
        }
        // Disk is the source of truth for the log bookkeeping too (an
        // external writer's appends count toward compaction pressure).
        let live = live_apps.values().filter(|v| **v).count();
        bk.total = total;
        bk.dead = total.saturating_sub(live);
        let mut index = self.write_index();
        match latest {
            Some(entry) => {
                index.insert(app.to_string(), entry);
            }
            None => {
                index.remove(app);
            }
        }
        Ok(())
    }
}

fn note_recovery(recovery: &Recovery, stats: &StoreStats) {
    if recovery.torn_bytes > 0 {
        stats.note_torn();
    }
    if recovery.quarantined_bytes > 0 {
        stats.note_quarantined(recovery.quarantined_bytes);
    }
}

/// Fold replayed payloads into the live index + bookkeeping.
fn fold(payloads: &[Vec<u8>]) -> (HashMap<String, Entry>, Bookkeeping) {
    let mut index: HashMap<String, Entry> = HashMap::new();
    let mut total = 0usize;
    for bytes in payloads {
        let Some(payload) = decode(bytes) else {
            // Checksummed but unintelligible (a future schema?): dead
            // weight until the next compaction.
            total += 1;
            continue;
        };
        total += 1;
        match payload {
            Payload::Record(entry) => {
                index.insert(entry.rec.app.clone(), entry);
            }
            Payload::Tombstone(app) => {
                index.remove(&app);
            }
        }
    }
    let dead = total - index.len();
    let bk = Bookkeeping { total, dead };
    (index, bk)
}
