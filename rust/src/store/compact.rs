//! When to rewrite a shard log.
//!
//! Appends never overwrite: every re-store, restamp, tombstone, and
//! eviction leaves a dead record behind in the log, and replay cost on
//! the next cold open grows with *total* records, not live ones. The
//! compaction policy bounds that growth without rewriting the log on
//! every mutation:
//!
//! * `min_dead` — don't bother below this many dead records; a rewrite
//!   costs a full shard serialization + atomic rename.
//! * `dead_ratio` — rewrite once dead records are at least this
//!   fraction of the log. At the default 0.5 a shard log is never more
//!   than ~2x its live size, so replay work stays proportional to the
//!   live record count.
//!
//! The check runs *after* a mutation has released the shard's writer
//! mutex (the mutex is not reentrant), so a storm of writers may each
//! see `wants_compaction` and queue up — [`maybe_compact`] re-checks
//! under the lock-free counters and at worst compacts an extra time,
//! which is correct, just redundant.

use anyhow::Result;

use super::shard::Shard;
use super::stats::StoreStats;

/// Tunables for the dead-record rewrite trigger.
#[derive(Debug, Clone, Copy)]
pub struct CompactionPolicy {
    /// Minimum dead records before a rewrite is worth the I/O.
    pub min_dead: usize,
    /// Dead fraction of the log (dead / total) that triggers a rewrite.
    pub dead_ratio: f64,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        CompactionPolicy {
            min_dead: 8,
            dead_ratio: 0.5,
        }
    }
}

impl CompactionPolicy {
    /// A policy that never triggers automatically (benches that want to
    /// measure raw append throughput, tests that inspect dead counts).
    pub fn never() -> Self {
        CompactionPolicy {
            min_dead: usize::MAX,
            dead_ratio: 1.0,
        }
    }
}

/// Compact `shard` if the policy says so. Returns the number of dead
/// records reclaimed (0 = no compaction ran).
pub(crate) fn maybe_compact(
    shard: &Shard,
    policy: &CompactionPolicy,
    stats: &StoreStats,
) -> Result<usize> {
    if !shard.wants_compaction(policy.min_dead, policy.dead_ratio) {
        return Ok(0);
    }
    let _span = crate::obs::span("store.compact");
    shard.compact(stats)
}
