//! Sharded, log-structured, crash-safe pattern store.
//!
//! The pattern DB is the product at service scale (every hit avoids
//! re-paying a verification-environment search that stands in for a
//! multi-hour HLS build), and the flat one-JSON-file-per-app layout
//! stopped scaling with it: every open re-read and re-parsed every
//! record, every concurrent writer contended on one global lock map,
//! and nothing ever got evicted. This module is the replacement — an
//! embedded store in the column-family spirit of log-structured KV
//! engines, sized for tens of thousands of records:
//!
//! * **Sharded** ([`index`]): records route to one of
//!   [`SHARD_COUNT`](index::SHARD_COUNT) append-only logs by FNV-1a of
//!   the app name. Each shard has its own writer mutex and its own
//!   in-memory index under a `RwLock`, so concurrent batch/service
//!   workers only serialize when they hit the *same* shard, and the
//!   service's synchronous hit path reads without waiting on any cold
//!   solve's log I/O.
//! * **Log-structured** ([`log`], [`shard`]): a store is an append of
//!   one length-prefixed, checksummed record; the live state is
//!   rebuilt by replaying the logs on open and then served from
//!   memory. Torn tails truncate, corrupt frames quarantine to
//!   `.corrupt` sidecars — a crash never costs a previously durable
//!   record.
//! * **Bounded** ([`evict`], [`compact`]): under a configured capacity
//!   the cheapest-to-recompute records (solve cost discounted by
//!   staleness) are tombstoned first, and shards whose dead-record
//!   fraction crosses the [`CompactionPolicy`] are rewritten in place.
//!
//! [`crate::envadapt::PatternDb`] and [`crate::envadapt::PatternIndex`]
//! are thin facades over this type, so the pipeline, the batch ladder,
//! the service tier, and the CLI all share one storage engine — and one
//! process-wide handle per directory (see [`index`]'s registry), which
//! is what makes a warm open O(1).

pub mod compact;
pub mod evict;
pub mod index;
pub mod log;
pub mod shard;
pub mod stats;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::envadapt::patterndb::{
    record_json, unix_now, ReuseKey, StoredPattern,
};
use crate::obs;
use crate::search::OffloadSolution;
use crate::util::json::Json;

pub use compact::CompactionPolicy;
pub use index::SHARD_COUNT;
pub use stats::{StoreStats, StoreStatsSnapshot};

use shard::{AppendOutcome, Entry, Shard};

/// Open-time tunables.
#[derive(Debug, Clone, Default)]
pub struct StoreConfig {
    /// Maximum live records across all shards (`None` = unbounded).
    /// Exceeding it evicts per [`evict`]'s cost-aware policy.
    pub capacity: Option<usize>,
    /// Dead-record rewrite trigger.
    pub compaction: CompactionPolicy,
}

/// What a legacy-layout migration did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationReport {
    /// Flat records appended into the shard logs.
    pub migrated: usize,
    /// Flat records dropped because the store already held a fresher
    /// record for the app (the normal freshness rule).
    pub skipped_stale: usize,
    /// Unparseable flat files quarantined to `.corrupt`.
    pub quarantined: usize,
}

/// The sharded pattern store. Obtain via [`PatternStore::open`]; all
/// methods take `&self` and are safe under arbitrary thread sharing.
#[derive(Debug)]
pub struct PatternStore {
    dir: PathBuf,
    shards: Vec<Shard>,
    stats: StoreStats,
    /// Live-record cap; 0 = unbounded. Runtime-settable (the service
    /// applies `--db-capacity` after open).
    capacity: AtomicUsize,
    compaction: CompactionPolicy,
}

impl PatternStore {
    /// Open the store on `dir` (created if needed). If this process
    /// already has the directory open, the existing handle is returned
    /// — shard locks, in-memory index, and counters are shared, and no
    /// replay happens (the warm-open path).
    pub fn open(dir: &Path) -> Result<Arc<PatternStore>> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating pattern DB dir {dir:?}"))?;
        if let Some(existing) = index::lookup(dir) {
            return Ok(existing);
        }
        let store = Self::replay(dir, StoreConfig::default())?;
        index::publish(dir, &store);
        Ok(store)
    }

    /// Open bypassing the process registry: always replays from disk
    /// and is *not* shared with (or visible to) other handles. For
    /// cold-open benches and crash-recovery tests; production code
    /// wants [`open`](Self::open).
    pub fn open_fresh(dir: &Path) -> Result<Arc<PatternStore>> {
        Self::open_fresh_with(dir, StoreConfig::default())
    }

    /// [`open_fresh`](Self::open_fresh) with explicit tunables.
    pub fn open_fresh_with(
        dir: &Path,
        config: StoreConfig,
    ) -> Result<Arc<PatternStore>> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating pattern DB dir {dir:?}"))?;
        Self::replay(dir, config)
    }

    fn replay(dir: &Path, config: StoreConfig) -> Result<Arc<PatternStore>> {
        let stats = StoreStats::default();
        let mut shards = Vec::with_capacity(SHARD_COUNT);
        for slot in 0..SHARD_COUNT {
            let path = dir.join(index::shard_file(slot));
            shards.push(Shard::open(&path, &stats)?);
        }
        Ok(Arc::new(PatternStore {
            dir: dir.to_path_buf(),
            shards,
            stats,
            capacity: AtomicUsize::new(config.capacity.unwrap_or(0)),
            compaction: config.compaction,
        }))
    }

    /// The directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn shard(&self, app: &str) -> &Shard {
        &self.shards[index::shard_of(app)]
    }

    /// The shard log an app's records are appended to (whether or not
    /// any exist yet).
    pub fn shard_path_of(&self, app: &str) -> PathBuf {
        self.shard(app).path().to_path_buf()
    }

    /// Live counters for this handle.
    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    /// Live record count across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(Shard::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dead (reclaimable) records across all shards.
    pub fn dead_records(&self) -> usize {
        self.shards.iter().map(Shard::dead).sum()
    }

    /// Current capacity (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        match self.capacity.load(Ordering::Relaxed) {
            0 => None,
            n => Some(n),
        }
    }

    /// Change the capacity. Takes effect on the next store (an
    /// over-capacity store is trimmed lazily, not eagerly).
    pub fn set_capacity(&self, capacity: Option<usize>) {
        self.capacity
            .store(capacity.unwrap_or(0), Ordering::Relaxed);
    }

    /// The live record for an app (no key check, no counters).
    pub fn get(&self, app: &str) -> Option<StoredPattern> {
        self.shard(app).get(app).map(|e| e.rec)
    }

    /// The full stored JSON for an app.
    pub fn load_json(&self, app: &str) -> Option<Json> {
        self.shard(app).get(app).map(|e| e.json)
    }

    /// Reuse-key lookup — the hit path. Counts a hit only when the
    /// record exists *and* matches the full key.
    pub fn lookup(
        &self,
        app: &str,
        key: &ReuseKey,
    ) -> Option<StoredPattern> {
        let _span = obs::span("store.read");
        match self.shard(app).get(app) {
            Some(e) if e.rec.matches(key) => {
                self.stats.note_hit();
                Some(e.rec)
            }
            _ => {
                self.stats.note_miss();
                None
            }
        }
    }

    /// Persist a solution. Keyed writes (`key.is_some()`) carry the
    /// full reuse key + `stored_at` stamp and obey the freshness rule;
    /// unkeyed writes overwrite unconditionally and are never reused.
    /// Returns the shard log path the record lives in.
    pub fn store_solution(
        &self,
        sol: &OffloadSolution,
        key: Option<&ReuseKey>,
        stamp: u64,
    ) -> Result<PathBuf> {
        let _span = obs::span("store.append");
        let json = record_json(sol, key, stamp);
        let Some(rec) = StoredPattern::from_json(&json, Some(&sol.app))
        else {
            anyhow::bail!("solution for {:?} did not serialize", sol.app);
        };
        let app = rec.app.clone();
        let shard = self.shard(&app);
        let stored = shard.store(
            Entry { rec, json },
            key.is_some(),
            &self.stats,
        )?;
        if stored == AppendOutcome::Stored {
            self.enforce_capacity(&app)?;
        }
        compact::maybe_compact(shard, &self.compaction, &self.stats)?;
        Ok(shard.path().to_path_buf())
    }

    /// Tombstone an app's record. Returns whether one was live.
    pub fn remove(&self, app: &str) -> Result<bool> {
        let shard = self.shard(app);
        let removed = shard.remove(app, &self.stats)?;
        compact::maybe_compact(shard, &self.compaction, &self.stats)?;
        Ok(removed)
    }

    /// Rewrite an app's record with a new `stored_at` stamp — the seam
    /// age-policy tests and operators use instead of editing log bytes.
    pub fn restamp(&self, app: &str, stamp: u64) -> Result<bool> {
        let shard = self.shard(app);
        let hit = shard.restamp(app, stamp, &self.stats)?;
        compact::maybe_compact(shard, &self.compaction, &self.stats)?;
        Ok(hit)
    }

    /// Re-sync one app's entry from its shard log on disk (external
    /// writers — another process on the same directory). Touches only
    /// the affected shard; every other shard's index is untouched.
    pub fn refresh(&self, app: &str) -> Result<()> {
        self.shard(app).refresh_app(app, &self.stats)
    }

    /// Apps with live records, sorted.
    pub fn list(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.entries().into_iter().map(|e| e.rec.app))
            .collect();
        out.sort();
        out
    }

    /// All live records, sorted by app.
    pub fn records(&self) -> Vec<StoredPattern> {
        let mut out: Vec<StoredPattern> = self
            .shards
            .iter()
            .flat_map(|s| s.entries().into_iter().map(|e| e.rec))
            .collect();
        out.sort_by(|a, b| a.app.cmp(&b.app));
        out
    }

    /// Compact every shard unconditionally (the `repro patterndb
    /// compact` path). Returns total dead records reclaimed.
    pub fn compact_all(&self) -> Result<usize> {
        let _span = obs::span("store.compact");
        let mut reclaimed = 0;
        for shard in &self.shards {
            reclaimed += shard.compact(&self.stats)?;
        }
        Ok(reclaimed)
    }

    /// Evict down to capacity, never touching `protect`.
    fn enforce_capacity(&self, protect: &str) -> Result<()> {
        let Some(cap) = self.capacity() else {
            return Ok(());
        };
        let len = self.len();
        if len <= cap {
            return Ok(());
        }
        let _span = obs::span("store.evict");
        let victims = evict::choose_victims(
            &self.records(),
            len - cap,
            protect,
            unix_now(),
        );
        for app in victims {
            if self.shard(&app).remove(&app, &self.stats)? {
                self.stats.note_eviction();
            }
        }
        Ok(())
    }

    /// Quarantined debris in the directory: shard-log `.corrupt`
    /// sidecars plus any legacy `<app>.pattern.json.corrupt` files
    /// (reported by app name, as before the sharded layout).
    pub fn quarantined(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(app) = name.strip_suffix(".pattern.json.corrupt") {
                out.push(app.to_string());
            } else if let Some(log) = name.strip_suffix(".corrupt") {
                out.push(log.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    /// Unmigrated legacy flat files still sitting in the directory.
    pub fn legacy_count(&self) -> usize {
        legacy_files(&self.dir).map(|v| v.len()).unwrap_or(0)
    }

    /// One-shot migration from the legacy one-file-per-app layout:
    /// every `<app>.pattern.json` in the directory is appended into its
    /// shard (payload preserved byte-for-byte as a record; the record's
    /// own `stored_at` drives the freshness rule) and the flat file is
    /// renamed to `.migrated`. Unparseable files quarantine to
    /// `.corrupt`. Idempotent: a second run finds nothing to do.
    pub fn migrate_legacy(&self) -> Result<MigrationReport> {
        let mut report = MigrationReport::default();
        for path in legacy_files(&self.dir)? {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {path:?}"))?;
            let parsed = Json::parse(&text).ok().and_then(|json| {
                let app = legacy_app_name(&path);
                StoredPattern::from_json(&json, app.as_deref())
                    .map(|rec| Entry { rec, json })
            });
            let Some(entry) = parsed else {
                let bytes = text.len() as u64;
                rename_suffix(&path, ".corrupt")?;
                self.stats.note_quarantined(bytes);
                report.quarantined += 1;
                continue;
            };
            let shard = self.shard(&entry.rec.app);
            match shard.store(entry, true, &self.stats)? {
                AppendOutcome::Stored => report.migrated += 1,
                AppendOutcome::DroppedStale => report.skipped_stale += 1,
            }
            rename_suffix(&path, ".migrated")?;
        }
        // The logs may now exceed capacity; trim once at the end.
        self.enforce_capacity("")?;
        for shard in &self.shards {
            compact::maybe_compact(shard, &self.compaction, &self.stats)?;
        }
        Ok(report)
    }

    /// Write every live record back out as legacy flat files under
    /// `out` (`<app>.pattern.json`) — the seed for migration smokes and
    /// the flat-file baseline the benches compare against. Returns the
    /// number of files written.
    pub fn export_legacy(&self, out: &Path) -> Result<usize> {
        std::fs::create_dir_all(out)
            .with_context(|| format!("creating export dir {out:?}"))?;
        let mut written = 0;
        for shard in &self.shards {
            for entry in shard.entries() {
                let path =
                    out.join(format!("{}.pattern.json", entry.rec.app));
                std::fs::write(&path, entry.json.pretty())
                    .with_context(|| format!("writing {path:?}"))?;
                written += 1;
            }
        }
        Ok(written)
    }

    /// Parse every legacy flat file under `dir` — the "cold flat scan"
    /// the old layout performed on every open, kept as the bench
    /// baseline and the migration dry-run.
    pub fn scan_legacy(dir: &Path) -> Result<Vec<StoredPattern>> {
        let mut out = Vec::new();
        for path in legacy_files(dir)? {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {path:?}"))?;
            let Ok(json) = Json::parse(&text) else {
                continue;
            };
            let app = legacy_app_name(&path);
            if let Some(rec) =
                StoredPattern::from_json(&json, app.as_deref())
            {
                out.push(rec);
            }
        }
        out.sort_by(|a, b| a.app.cmp(&b.app));
        Ok(out)
    }
}

/// `<app>.pattern.json` files in `dir`, sorted for determinism.
fn legacy_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(out)
        }
        Err(e) => {
            return Err(e).with_context(|| format!("reading dir {dir:?}"))
        }
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.ends_with(".pattern.json") {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

fn legacy_app_name(path: &Path) -> Option<String> {
    path.file_name()
        .and_then(|n| n.to_str())
        .and_then(|n| n.strip_suffix(".pattern.json"))
        .map(String::from)
}

fn rename_suffix(path: &Path, suffix: &str) -> Result<()> {
    let mut target = path.as_os_str().to_owned();
    target.push(suffix);
    std::fs::rename(path, &target)
        .with_context(|| format!("renaming {path:?} -> {target:?}"))?;
    Ok(())
}
