//! Record framing for the append-only shard logs: length-prefixed,
//! checksummed, crash-safe.
//!
//! Every durable byte the pattern store writes goes through this module,
//! and the same helpers back the [`crate::envadapt::TestDb`] /
//! [`crate::envadapt::FacilityDb`] persistence paths, so there is exactly
//! one framing/recovery implementation in the repo.
//!
//! ## Frame format
//!
//! ```text
//! [u32 LE payload length][u64 LE FNV-1a(payload)][payload bytes]
//! ```
//!
//! A record is valid only if the full frame is present *and* the
//! checksum matches. Recovery ([`replay`]) distinguishes the two ways a
//! log can be damaged:
//!
//! * **Torn tail** — the file ends mid-frame (a crash between `write`
//!   and completion). Everything before the tear is intact; the tail is
//!   truncated away and replay reports how many bytes were dropped.
//! * **Corruption** — a frame whose checksum does not match its payload
//!   (bit rot, a hand edit, overlapping writers from a foreign process).
//!   Framing downstream of a corrupt record cannot be trusted, so the
//!   remainder of the file is *quarantined*: moved verbatim into a
//!   `.corrupt` sidecar for inspection, then truncated out of the log —
//!   the same "preserve, don't serve" policy the flat-file store applied
//!   per app.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Frame header size: u32 length + u64 checksum.
pub const FRAME_HEADER: usize = 12;

/// Payloads above this are rejected as corruption during replay (no
/// legitimate record is remotely this large; a garbage length would
/// otherwise make replay "wait" for gigabytes that never existed).
pub const MAX_PAYLOAD: usize = 1 << 28;

/// 64-bit FNV-1a over a byte slice — the same hash family the reuse
/// keys and shard router use.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Append one framed payload to `buf`.
pub fn push_frame(buf: &mut Vec<u8>, payload: &[u8]) {
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&fnv1a(payload).to_le_bytes());
    buf.extend_from_slice(payload);
}

/// Append one framed record to the log at `path` (created if absent).
/// The frame is assembled in memory and handed to the kernel in a
/// single `write`, so a crash can tear the *tail* of a record but never
/// interleave two records.
pub fn append(path: &Path, payload: &[u8]) -> Result<()> {
    let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len());
    push_frame(&mut frame, payload);
    let mut file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening log {path:?}"))?;
    file.write_all(&frame)
        .with_context(|| format!("appending to log {path:?}"))?;
    file.flush()
        .with_context(|| format!("flushing log {path:?}"))?;
    Ok(())
}

/// Atomically replace the file at `path` with the framed `payloads`
/// (compaction, whole-file snapshots): write a scratch file in the same
/// directory, then rename it over the destination. A crash mid-write
/// leaves only the scratch file, which no read path looks at.
pub fn write_atomic(path: &Path, payloads: &[&[u8]]) -> Result<()> {
    let total: usize =
        payloads.iter().map(|p| FRAME_HEADER + p.len()).sum();
    let mut buf = Vec::with_capacity(total);
    for payload in payloads {
        push_frame(&mut buf, payload);
    }
    let tmp = scratch_path(path);
    std::fs::write(&tmp, &buf)
        .with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    Ok(())
}

/// Per-writer scratch-file name next to `path` (same filesystem, so the
/// rename is atomic; pid + counter so concurrent writers never share).
fn scratch_path(path: &Path) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let mut name = path.as_os_str().to_owned();
    name.push(format!(
        ".{}-{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::path::PathBuf::from(name)
}

/// What [`replay`] found besides the valid records.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Recovery {
    /// Bytes of torn tail truncated away (0 = the log ended cleanly).
    pub torn_bytes: u64,
    /// Bytes quarantined to the `.corrupt` sidecar after a checksum
    /// mismatch (0 = no corruption).
    pub quarantined_bytes: u64,
}

impl Recovery {
    pub fn clean(&self) -> bool {
        self.torn_bytes == 0 && self.quarantined_bytes == 0
    }
}

/// Replay a log: return every valid payload in append order, repairing
/// the file in place per the module policy (torn tail truncated, the
/// remainder after a corrupt frame quarantined to `<path>.corrupt` and
/// truncated). A missing file replays as empty.
pub fn replay(path: &Path) -> Result<(Vec<Vec<u8>>, Recovery)> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((Vec::new(), Recovery::default()))
        }
        Err(e) => {
            return Err(e).with_context(|| format!("reading log {path:?}"))
        }
    };
    let (records, valid_up_to, damage) = scan(&bytes);
    let mut recovery = Recovery::default();
    match damage {
        Damage::None => {}
        Damage::TornTail => {
            recovery.torn_bytes = (bytes.len() - valid_up_to) as u64;
            truncate(path, valid_up_to)?;
        }
        Damage::Corrupt => {
            recovery.quarantined_bytes =
                (bytes.len() - valid_up_to) as u64;
            quarantine(path, &bytes[valid_up_to..])?;
            truncate(path, valid_up_to)?;
        }
    }
    Ok((records, recovery))
}

/// Non-destructive replay: valid payloads only, no file repair. The
/// loader for single-snapshot DB files (test-case / facility DBs),
/// where a torn tail simply means "the previous save survives".
pub fn read_frames(path: &Path) -> Result<Vec<Vec<u8>>> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new())
        }
        Err(e) => {
            return Err(e).with_context(|| format!("reading {path:?}"))
        }
    };
    Ok(scan(&bytes).0)
}

enum Damage {
    None,
    TornTail,
    Corrupt,
}

/// Walk the frames in `bytes`: valid payloads, the offset where
/// validity ends, and what kind of damage (if any) starts there.
fn scan(bytes: &[u8]) -> (Vec<Vec<u8>>, usize, Damage) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let rest = &bytes[pos..];
        if rest.len() < FRAME_HEADER {
            return (records, pos, Damage::TornTail);
        }
        let len =
            u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
        if len > MAX_PAYLOAD {
            // A length no writer ever produces: corruption, not a tear.
            return (records, pos, Damage::Corrupt);
        }
        if rest.len() < FRAME_HEADER + len {
            return (records, pos, Damage::TornTail);
        }
        let sum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
        let payload = &rest[FRAME_HEADER..FRAME_HEADER + len];
        if fnv1a(payload) != sum {
            return (records, pos, Damage::Corrupt);
        }
        records.push(payload.to_vec());
        pos += FRAME_HEADER + len;
    }
    (records, pos, Damage::None)
}

fn truncate(path: &Path, len: usize) -> Result<()> {
    let file = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("opening {path:?} for repair"))?;
    file.set_len(len as u64)
        .with_context(|| format!("truncating {path:?} to {len}"))?;
    Ok(())
}

/// Where a log's quarantined bytes land.
pub fn corrupt_sidecar(path: &Path) -> std::path::PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(".corrupt");
    std::path::PathBuf::from(name)
}

fn quarantine(path: &Path, bytes: &[u8]) -> Result<()> {
    let sidecar = corrupt_sidecar(path);
    let mut file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&sidecar)
        .with_context(|| format!("opening quarantine {sidecar:?}"))?;
    file.write_all(bytes)
        .with_context(|| format!("writing quarantine {sidecar:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn append_replay_roundtrip() {
        let dir = TempDir::new("store-log").unwrap();
        let path = dir.join("a.log");
        append(&path, b"one").unwrap();
        append(&path, b"two").unwrap();
        append(&path, b"").unwrap();
        let (records, rec) = replay(&path).unwrap();
        assert_eq!(records, vec![b"one".to_vec(), b"two".to_vec(), vec![]]);
        assert!(rec.clean());
    }

    #[test]
    fn missing_log_replays_empty() {
        let dir = TempDir::new("store-log").unwrap();
        let (records, rec) = replay(&dir.join("nope.log")).unwrap();
        assert!(records.is_empty());
        assert!(rec.clean());
    }

    #[test]
    fn torn_tail_is_truncated_every_prior_record_survives() {
        let dir = TempDir::new("store-log").unwrap();
        let path = dir.join("a.log");
        append(&path, b"alpha").unwrap();
        append(&path, b"beta").unwrap();
        let full = std::fs::read(&path).unwrap();
        let second_start = FRAME_HEADER + 5;
        // Every possible crash point inside the second record.
        for cut in second_start..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let (records, rec) = replay(&path).unwrap();
            assert_eq!(records, vec![b"alpha".to_vec()], "cut at {cut}");
            assert_eq!(rec.torn_bytes, (cut - second_start) as u64);
            assert_eq!(rec.quarantined_bytes, 0);
            // The repair truncated the tear: a second replay is clean.
            let (again, rec2) = replay(&path).unwrap();
            assert_eq!(again.len(), 1);
            assert!(rec2.clean(), "cut at {cut}: {rec2:?}");
        }
    }

    #[test]
    fn corrupt_frame_quarantines_the_rest() {
        let dir = TempDir::new("store-log").unwrap();
        let path = dir.join("a.log");
        append(&path, b"alpha").unwrap();
        append(&path, b"beta").unwrap();
        append(&path, b"gamma").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a payload byte of the second record.
        let flip = FRAME_HEADER + 5 + FRAME_HEADER;
        bytes[flip] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (records, rec) = replay(&path).unwrap();
        assert_eq!(records, vec![b"alpha".to_vec()]);
        assert!(rec.quarantined_bytes > 0);
        assert_eq!(rec.torn_bytes, 0);
        // The damaged bytes are preserved for inspection, out of band.
        let sidecar = corrupt_sidecar(&path);
        assert_eq!(
            std::fs::read(&sidecar).unwrap().len() as u64,
            rec.quarantined_bytes
        );
        // The log itself is clean again and appendable.
        append(&path, b"delta").unwrap();
        let (records, rec) = replay(&path).unwrap();
        assert_eq!(records, vec![b"alpha".to_vec(), b"delta".to_vec()]);
        assert!(rec.clean());
    }

    #[test]
    fn absurd_length_reads_as_corruption_not_a_wait() {
        let dir = TempDir::new("store-log").unwrap();
        let path = dir.join("a.log");
        append(&path, b"alpha").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mut garbage = ((MAX_PAYLOAD + 1) as u32).to_le_bytes().to_vec();
        garbage.extend_from_slice(&[0u8; 16]);
        bytes.extend_from_slice(&garbage);
        std::fs::write(&path, &bytes).unwrap();
        let (records, rec) = replay(&path).unwrap();
        assert_eq!(records, vec![b"alpha".to_vec()]);
        assert!(rec.quarantined_bytes > 0);
    }

    #[test]
    fn write_atomic_replaces_wholesale() {
        let dir = TempDir::new("store-log").unwrap();
        let path = dir.join("a.log");
        append(&path, b"old1").unwrap();
        append(&path, b"old2").unwrap();
        write_atomic(&path, &[b"new"]).unwrap();
        let (records, rec) = replay(&path).unwrap();
        assert_eq!(records, vec![b"new".to_vec()]);
        assert!(rec.clean());
        // No scratch files left behind.
        let stray: Vec<_> = std::fs::read_dir(dir.path())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "a.log")
            .collect();
        assert!(stray.is_empty(), "{stray:?}");
    }
}
