//! Operational counters for a [`crate::store::PatternStore`] handle.
//!
//! Everything here is a relaxed atomic: counters are advisory telemetry
//! for the `stats` surfaces (service [`StatsSnapshot`], `repro patterndb
//! stats`), never control flow. They tally since *open* of this handle —
//! a fresh process starts from zero even over a populated store.
//!
//! [`StatsSnapshot`]: crate::service::StatsSnapshot

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Live counters owned by a store handle (shared by every facade —
/// `PatternDb`, `PatternIndex`, the service — opened on the same dir in
/// this process, since they share the handle itself).
#[derive(Debug, Default)]
pub struct StoreStats {
    /// Keyed lookups that found a record matching the full reuse key.
    pub(crate) hits: AtomicU64,
    /// Keyed lookups that found nothing (or a non-matching record).
    pub(crate) misses: AtomicU64,
    /// Hits whose record was older than the caller's age policy —
    /// counted by the policy layer (the service's probe), since the
    /// store itself has no age opinion.
    pub(crate) stale_hits: AtomicU64,
    /// Records appended to a shard log (stores, restamps, migrations).
    pub(crate) appends: AtomicU64,
    /// Keyed writes dropped by the freshness rule (an older stamp
    /// arriving after a newer record).
    pub(crate) stale_writes_dropped: AtomicU64,
    /// Records evicted under the capacity policy.
    pub(crate) evictions: AtomicU64,
    /// Shard compactions performed.
    pub(crate) compactions: AtomicU64,
    /// Bytes quarantined to `.corrupt` sidecars during recovery.
    pub(crate) quarantined_bytes: AtomicU64,
    /// Torn-tail truncations performed during recovery.
    pub(crate) torn_truncations: AtomicU64,
}

macro_rules! bump {
    ($($name:ident => $field:ident),* $(,)?) => {
        $(pub(crate) fn $name(&self) {
            self.$field.fetch_add(1, Ordering::Relaxed);
        })*
    };
}

impl StoreStats {
    bump! {
        note_hit => hits,
        note_miss => misses,
        note_append => appends,
        note_stale_write => stale_writes_dropped,
        note_eviction => evictions,
        note_compaction => compactions,
        note_torn => torn_truncations,
    }

    /// Count a hit that the caller's age policy judged stale. Public via
    /// [`count_stale`](StoreStatsSnapshot) consumers: the service's
    /// probe calls this when a matching record exceeds `max_age`.
    pub fn note_stale_hit(&self) {
        self.stale_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_quarantined(&self, bytes: u64) {
        self.quarantined_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> StoreStatsSnapshot {
        let get = |a: &AtomicU64| a.load(Ordering::Relaxed);
        StoreStatsSnapshot {
            hits: get(&self.hits),
            misses: get(&self.misses),
            stale_hits: get(&self.stale_hits),
            appends: get(&self.appends),
            stale_writes_dropped: get(&self.stale_writes_dropped),
            evictions: get(&self.evictions),
            compactions: get(&self.compactions),
            quarantined_bytes: get(&self.quarantined_bytes),
            torn_truncations: get(&self.torn_truncations),
        }
    }
}

/// Frozen [`StoreStats`] values.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub stale_hits: u64,
    pub appends: u64,
    pub stale_writes_dropped: u64,
    pub evictions: u64,
    pub compactions: u64,
    pub quarantined_bytes: u64,
    pub torn_truncations: u64,
}

impl StoreStatsSnapshot {
    /// The store-owned slice of the service stats JSON. Keys are flat so
    /// smoke tests and dashboards address them without nesting.
    pub fn to_json_fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("store_hits", Json::Num(self.hits as f64)),
            ("store_misses", Json::Num(self.misses as f64)),
            ("stale_hits", Json::Num(self.stale_hits as f64)),
            ("appends", Json::Num(self.appends as f64)),
            (
                "stale_writes_dropped",
                Json::Num(self.stale_writes_dropped as f64),
            ),
            ("evictions", Json::Num(self.evictions as f64)),
            ("compactions", Json::Num(self.compactions as f64)),
            (
                "quarantined_bytes",
                Json::Num(self.quarantined_bytes as f64),
            ),
            (
                "torn_truncations",
                Json::Num(self.torn_truncations as f64),
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_start_zero_and_tally() {
        let s = StoreStats::default();
        assert_eq!(s.snapshot(), StoreStatsSnapshot::default());
        s.note_hit();
        s.note_hit();
        s.note_miss();
        s.note_stale_hit();
        s.note_eviction();
        s.note_compaction();
        s.note_quarantined(17);
        let snap = s.snapshot();
        assert_eq!(snap.hits, 2);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.stale_hits, 1);
        assert_eq!(snap.evictions, 1);
        assert_eq!(snap.compactions, 1);
        assert_eq!(snap.quarantined_bytes, 17);
    }

    #[test]
    fn json_fields_cover_the_smoke_contract() {
        let snap = StoreStats::default().snapshot();
        let keys: Vec<&str> =
            snap.to_json_fields().iter().map(|(k, _)| *k).collect();
        for required in ["evictions", "compactions", "stale_hits"] {
            assert!(keys.contains(&required), "{required} missing");
        }
    }
}
