//! Minimal benchmark harness (criterion substitute for the offline
//! environment). Used by the `rust/benches/*.rs` targets, which are
//! declared with `harness = false`.
//!
//! Measures wall-clock over warmup + timed iterations and prints
//! criterion-style lines; also offers simple aligned tables for the
//! paper-reproduction benches, and writes machine-readable results into
//! `target/bench-results/<name>.json` for EXPERIMENTS.md bookkeeping.

use std::time::{Duration, Instant};

use super::json::Json;

/// Timing statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Time `f` with `warmup` + `iters` runs; prints a summary line.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    let stats = Stats {
        name: name.to_string(),
        iters: iters.max(1),
        mean: total / iters.max(1),
        min: *times.iter().min().unwrap(),
        max: *times.iter().max().unwrap(),
    };
    println!(
        "{:<44} time: [{:>10.3?} {:>10.3?} {:>10.3?}]  ({} iters)",
        stats.name, stats.min, stats.mean, stats.max, stats.iters
    );
    stats
}

/// Aligned table printer for result matrices.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, (c, w)) in cells.iter().zip(&widths).enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{c:>w$}"));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Persist a bench's result object under `target/bench-results/`.
pub fn save_results(bench_name: &str, value: &Json) {
    let dir = std::path::Path::new("target/bench-results");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(format!("{bench_name}.json"));
        let _ = std::fs::write(path, value.pretty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench("test", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn table_alignment_no_panic() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.print();
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
