//! Minimal JSON implementation (parse + serialize).
//!
//! Used for `artifacts/meta.json`, the env-adapt DB stores, and bench
//! report emission. Supports the full JSON value model; numbers are f64
//! (adequate for our metadata). No serde in the offline crate set — this
//! is the substitution.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `get(&["shapes", "tdfir", "m"])`.
    pub fn get(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.as_obj()?.get(*key)?;
        }
        Some(cur)
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bump() != Some(b'\\')
                                    || self.bump() != Some(b'u')
                                {
                                    return Err(
                                        self.err("lone high surrogate")
                                    );
                                }
                                let lo = self.hex4()?;
                                let combined = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(combined)
                                    .ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                b => {
                    // Re-decode multi-byte UTF-8 in place.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_compact(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    /// Pretty serialization with 2-space indent (for DB files humans read).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    pad(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    pad(out, depth + 1);
                    escape_into(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push('}');
            }
            other => other.write_compact(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#)
            .unwrap();
        assert_eq!(
            v.get(&["a"]).unwrap().as_arr().unwrap()[2]
                .get(&["b"])
                .unwrap(),
            &Json::Null
        );
        assert_eq!(v.get(&["c"]).unwrap().as_str().unwrap(), "d");
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A é");
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x"],"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("b", Json::Str("x".into())),
        ]);
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn reject_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn meta_json_shape() {
        // The exact access pattern runtime::artifacts uses.
        let text = r#"{"shapes":{"tdfir":{"m":8,"n":1024,"k":32}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(
            v.get(&["shapes", "tdfir", "m"]).unwrap().as_usize(),
            Some(8)
        );
    }
}
