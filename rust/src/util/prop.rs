//! Minimal property-based testing harness (proptest substitute).
//!
//! Runs a property over `n` generated cases; on failure it re-runs the
//! property on progressively "smaller" inputs produced by the case's
//! shrinker and reports the smallest failing case. Deterministic per seed
//! so CI failures reproduce.
//!
//! Usage:
//! ```ignore
//! prop::check(256, |rng| {
//!     let xs = prop::vec_u32(rng, 0..64, 0..100);
//!     prop::holds(my_invariant(&xs), format!("xs={xs:?}"))
//! });
//! ```

use super::rng::Pcg32;

/// Outcome of one property evaluation.
pub enum Outcome {
    Pass,
    Fail(String),
}

/// Assert helper: passes when `cond` holds, otherwise fails with `msg`.
pub fn holds(cond: bool, msg: impl Into<String>) -> Outcome {
    if cond {
        Outcome::Pass
    } else {
        Outcome::Fail(msg.into())
    }
}

/// Run `prop` on `cases` seeded inputs; panic with the first failure.
///
/// The property receives a fresh deterministic RNG per case. Seeds are
/// derived from the case index so a failure message's case id is enough
/// to reproduce locally.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Outcome,
{
    for case in 0..cases {
        let mut rng = Pcg32::new(0x5eed_0000 + case, case);
        if let Outcome::Fail(msg) = prop(&mut rng) {
            panic!("property failed at case {case}: {msg}");
        }
    }
}

/// Generate a vec of u32 with length in `len_range`, values in `val_range`.
pub fn vec_u32(
    rng: &mut Pcg32,
    len_range: std::ops::Range<usize>,
    val_range: std::ops::Range<u32>,
) -> Vec<u32> {
    let len = len_range.start + rng.index(len_range.end - len_range.start);
    (0..len)
        .map(|_| val_range.start + rng.below(val_range.end - val_range.start))
        .collect()
}

/// Uniform integer in `lo..hi` (generator helper for structured inputs
/// like random-program shapes).
pub fn int_in(rng: &mut Pcg32, lo: i64, hi: i64) -> i64 {
    assert!(lo < hi, "int_in({lo}, {hi})");
    lo + rng.below((hi - lo) as u32) as i64
}

/// Pick an index with the given relative weights (generator helper:
/// lets a program generator prefer common constructs while still
/// covering rare ones).
pub fn weighted(rng: &mut Pcg32, weights: &[u32]) -> usize {
    let total: u32 = weights.iter().sum();
    assert!(total > 0, "weighted: all-zero weights");
    let mut x = rng.below(total);
    for (i, w) in weights.iter().enumerate() {
        if x < *w {
            return i;
        }
        x -= w;
    }
    unreachable!("weighted: out of range")
}

/// Generate a vec of f64 in `[lo, hi)` with length in `len_range`.
pub fn vec_f64(
    rng: &mut Pcg32,
    len_range: std::ops::Range<usize>,
    lo: f64,
    hi: f64,
) -> Vec<f64> {
    let len = len_range.start + rng.index(len_range.end - len_range.start);
    (0..len).map(|_| lo + (hi - lo) * rng.f64()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |rng| {
            let v = vec_u32(rng, 0..16, 0..100);
            holds(v.len() < 16, "len bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(64, |rng| {
            let v = vec_u32(rng, 1..8, 0..10);
            holds(v.iter().sum::<u32>() < 5, format!("{v:?}"))
        });
    }

    #[test]
    fn int_in_and_weighted_respect_bounds() {
        check(128, |rng| {
            let v = int_in(rng, -3, 9);
            let w = weighted(rng, &[1, 0, 5, 2]);
            holds(
                (-3..9).contains(&v) && w < 4 && w != 1,
                format!("v={v} w={w}"),
            )
        });
    }

    #[test]
    fn generators_respect_ranges() {
        check(128, |rng| {
            let v = vec_u32(rng, 2..10, 5..20);
            let ok = v.len() >= 2
                && v.len() < 10
                && v.iter().all(|&x| (5..20).contains(&x));
            holds(ok, format!("{v:?}"))
        });
    }
}
