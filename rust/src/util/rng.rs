//! Seedable PCG32 random number generator.
//!
//! Used by the GA search baseline, the workload sample-data generators,
//! and the property-test harness. PCG-XSH-RR 64/32 (O'Neill 2014):
//! small, fast, statistically solid, and fully deterministic across
//! platforms — determinism matters because measured offload patterns and
//! GA trajectories are asserted in tests.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create from a seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's method (no modulo bias).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        assert!(bound > 0 && bound <= u32::MAX as usize);
        self.below(bound as u32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — data generation is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg32::seeded(7);
        for bound in [1u32, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut rng = Pcg32::seeded(3);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[rng.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
