//! Unique, self-cleaning temporary directories.
//!
//! Tests (and examples) used to share fixed-name directories under
//! `std::env::temp_dir()` — e.g. `fpga_offload_flow_test` — which collide
//! when the test harness runs them in parallel: one test's cleanup races
//! another's `PatternDb` writes. A pid + process-global counter makes
//! every instance unique, and `Drop` removes the tree so nothing leaks
//! between runs even on panic-unwind.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp dir that is removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `<system-tmp>/<prefix>-<pid>-<counter>`.
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("{prefix}-{}-{n}", std::process::id()));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the directory.
    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirs_are_unique_and_cleaned() {
        let a = TempDir::new("fpga-offload-tempdir").unwrap();
        let b = TempDir::new("fpga-offload-tempdir").unwrap();
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir());
        let kept = a.path().to_path_buf();
        std::fs::write(a.join("x.json"), "{}").unwrap();
        drop(a);
        assert!(!kept.exists());
        assert!(b.path().is_dir());
    }
}
