//! Small self-contained substrates the offline build environment forces us
//! to own: JSON, a seedable RNG, a property-testing harness, and unique
//! self-cleaning temp dirs.

pub mod bench;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tempdir;
