//! Small self-contained substrates the offline build environment forces us
//! to own: JSON, a seedable RNG, a property-testing harness, and unique
//! self-cleaning temp dirs.
//!
//! Every report the crate writes (batch JSON, bench series, pattern-DB
//! records) round-trips through [`json`]:
//!
//! ```
//! use fpga_offload::util::json::Json;
//!
//! let v = Json::obj(vec![
//!     ("speedup", Json::Num(3.49)),
//!     ("destination", Json::Str("fpga".into())),
//! ]);
//! let text = v.pretty();
//! assert_eq!(Json::parse(&text).unwrap(), v);
//! assert_eq!(v.get(&["destination"]).unwrap().as_str(), Some("fpga"));
//! ```

pub mod bench;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tempdir;
