//! Small self-contained substrates the offline build environment forces us
//! to own: JSON, a seedable RNG, and a property-testing harness.

pub mod bench;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod rng;
