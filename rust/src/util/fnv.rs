//! FNV-1a hashing (§Perf optimization 1, EXPERIMENTS.md).
//!
//! The interpreter's hot path is name → value resolution in scoped
//! hash maps. std's default SipHash is DoS-resistant but slow for short
//! keys; variable names are attacker-free, so FNV-1a (a multiply/xor per
//! byte) is the right trade. Measured on the tdfir profiling run: see
//! EXPERIMENTS.md §Perf.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// 64-bit FNV-1a.
#[derive(Default)]
pub struct FnvHasher(u64);

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

impl Hasher for FnvHasher {
    fn finish(&self) -> u64 {
        self.0 ^ FNV_OFFSET // mix so a fresh hasher isn't 0
    }

    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { FNV_OFFSET } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }
}

/// A HashMap using FNV-1a.
pub type FnvMap<K, V> = HashMap<K, V, BuildHasherDefault<FnvHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basics() {
        let mut m: FnvMap<String, i32> = FnvMap::default();
        m.insert("alpha".into(), 1);
        m.insert("beta".into(), 2);
        assert_eq!(m.get("alpha"), Some(&1));
        assert_eq!(m.get("beta"), Some(&2));
        assert_eq!(m.get("gamma"), None);
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        use std::hash::Hash;
        let hash = |s: &str| {
            let mut h = FnvHasher::default();
            s.hash(&mut h);
            h.finish()
        };
        let names = ["i", "j", "k", "acc", "accr", "acci", "outr", "outi"];
        let hashes: std::collections::BTreeSet<u64> =
            names.iter().map(|n| hash(n)).collect();
        assert_eq!(hashes.len(), names.len());
    }
}
