//! `repro` — leader entrypoint for the automatic-FPGA-offloading
//! coordinator. Thin shell over [`fpga_offload::cli`].

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = fpga_offload::cli::run(&args);
    std::process::exit(code);
}
