//! Fig. 4 reproduction: performance improvement of the automatic FPGA
//! offloading solution vs all-CPU, for both evaluated applications.
//!
//! Paper: tdfir 4.0x, MRI-Q 7.1x. The absolute numbers come from the
//! calibrated Arria10/Xeon models (DESIGN.md §2); the claims under test
//! are the magnitudes (≈4x / ≈7x) and the ordering (MRI-Q > tdfir).

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{search, SearchConfig};
use fpga_offload::util::bench::{bench, save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn solve(app: &str, src: &str) -> fpga_offload::search::OffloadSolution {
    let prog = parse(src).expect("parse");
    let an = analyze(&prog, "main").expect("profile");
    search(
        app,
        &prog,
        &an,
        &SearchConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    )
    .expect("search")
}

fn main() {
    println!("== Fig. 4: performance improvement of automatic FPGA offloading ==\n");

    let apps = [
        ("tdfir", workloads::TDFIR_C, 4.0),
        ("mriq", workloads::MRIQ_C, 7.1),
    ];

    let mut table = Table::new(&[
        "application",
        "paper",
        "measured",
        "pattern",
        "patterns measured",
        "automation h",
    ]);
    let mut results = Vec::new();
    let mut speedups = Vec::new();

    for (app, src, paper) in apps {
        // Time the full search itself (the coordinator hot path).
        let mut sol = None;
        bench(&format!("fig4/search/{app}"), 0, 3, || {
            sol = Some(solve(app, src));
        });
        let sol = sol.unwrap();
        table.row(&[
            app.to_string(),
            format!("{paper:.1}x"),
            format!("{:.2}x", sol.speedup()),
            sol.best_measurement().label(),
            sol.measurements.len().to_string(),
            format!("{:.1}", sol.automation_s / 3600.0),
        ]);
        results.push((app, sol.speedup()));
        speedups.push(sol.speedup());
    }

    println!();
    table.print();

    // Shape assertions (who wins, by roughly what factor).
    let (tdfir, mriq) = (speedups[0], speedups[1]);
    assert!(
        (2.5..7.0).contains(&tdfir),
        "tdfir speedup {tdfir:.2} not in the paper's ballpark (4.0x)"
    );
    assert!(
        (5.0..10.0).contains(&mriq),
        "mriq speedup {mriq:.2} not in the paper's ballpark (7.1x)"
    );
    assert!(mriq > tdfir, "paper ordering: MRI-Q > tdfir");
    println!("\nshape check: PASS (tdfir≈4x, mriq≈7x, mriq > tdfir)");

    save_results(
        "fig4_speedup",
        &Json::obj(
            results
                .iter()
                .map(|(app, s)| (*app, Json::Num(*s)))
                .collect(),
        ),
    );
}
