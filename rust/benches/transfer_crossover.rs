//! Ablation: the CPU/FPGA crossover as compute density varies (§2's
//! motivation: "naive parallel processing performances with FPGAs or GPUs
//! are not high because of overheads of CPU and FPGA/GPU devices memory
//! data transfer").
//!
//! A synthetic elementwise loop is swept from pure copy (0 trig calls per
//! element) to trig-dense (4 calls). Low densities must LOSE when
//! offloaded (transfer-dominated), high densities must win — the
//! landscape that makes arithmetic-intensity narrowing meaningful.

use fpga_offload::analysis::analyze;
use fpga_offload::codegen::split;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::fpga::simulate;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::ast::LoopId;
use fpga_offload::minic::parse;
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;

fn app_with_density(trig_calls: usize) -> String {
    let expr = match trig_calls {
        0 => "a[i]".to_string(),
        n => {
            let mut e = "a[i]".to_string();
            for k in 0..n {
                let f = ["sin", "cos", "sqrt", "exp"][k % 4];
                e = format!("{f}({e} + 0.1)");
            }
            e
        }
    };
    format!(
        "#define N 8192\nfloat a[N]; float b[N];\n\
         int main() {{\n\
           for (int i = 0; i < N; i++) {{ a[i] = (i % 97) * 0.01; }}\n\
           for (int i = 0; i < N; i++) {{ b[i] = {expr}; }}\n\
           return 0;\n\
         }}"
    )
}

fn main() {
    println!("== transfer/compute crossover (synthetic elementwise loop) ==\n");
    let mut table = Table::new(&[
        "trig calls/elem", "speedup", "verdict",
    ]);
    let mut speedups = Vec::new();
    let mut results = Vec::new();

    for density in [0usize, 1, 2, 3, 4] {
        let src = app_with_density(density);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let al = an.loop_by_id(LoopId(1)).unwrap();
        let sp = split(&prog, al).unwrap();
        let t = simulate(&an, &[sp.kernel], &XEON_BRONZE_3104, &ARRIA10_GX)
            .unwrap();
        table.row(&[
            density.to_string(),
            format!("{:.2}x", t.speedup),
            if t.speedup > 1.0 { "offload" } else { "stay on CPU" }.into(),
        ]);
        speedups.push(t.speedup);
        results.push(Json::Arr(vec![
            Json::Num(density as f64),
            Json::Num(t.speedup),
        ]));
    }
    table.print();

    // Shape: monotone in density; copy loses, dense wins, a crossover
    // exists in between.
    for w in speedups.windows(2) {
        assert!(w[1] >= w[0] * 0.98, "speedup must not fall with density");
    }
    assert!(speedups[0] < 1.0, "pure copy must lose: {:.2}", speedups[0]);
    assert!(
        *speedups.last().unwrap() > 2.0,
        "trig-dense must win clearly"
    );
    println!("\nshape check: PASS (copy loses, dense wins, crossover in between)");
    save_results("transfer_crossover", &Json::Arr(results));
}
