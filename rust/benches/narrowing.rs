//! Ablation: funnel widths A (intensity) and C (resource efficiency) vs
//! solution quality and measurement cost, on tdfir.
//!
//! The paper fixes A=5, C=3 (§5.1.2). This sweep shows the trade the
//! numbers buy: narrower funnels risk missing the winner; wider funnels
//! buy nothing but compiles. Solution quality is scored against the
//! exhaustive single-loop optimum.

use fpga_offload::analysis::analyze;
use fpga_offload::codegen::split;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::fpga::simulate;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{search, SearchConfig};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!("== ablation: funnel widths A and C (tdfir) ==\n");
    let prog = parse(workloads::TDFIR_C).unwrap();
    let an = analyze(&prog, "main").unwrap();

    // Exhaustive single-loop optimum (the oracle).
    let mut oracle = 1.0f64;
    for al in &an.loops {
        if !al.candidate() {
            continue;
        }
        let Ok(sp) = split(&prog, al) else { continue };
        if let Ok(t) =
            simulate(&an, &[sp.kernel], &XEON_BRONZE_3104, &ARRIA10_GX)
        {
            oracle = oracle.max(t.speedup);
        }
    }
    println!("exhaustive single-loop oracle: {oracle:.2}x\n");

    let mut table = Table::new(&[
        "A", "C", "measured", "speedup", "vs oracle", "hit",
    ]);
    let mut results = Vec::new();
    for a in [1usize, 2, 3, 5, 8] {
        for c in [1usize, 2, 3].iter().copied().filter(|c| *c <= a) {
            let cfg = SearchConfig {
                top_a: a,
                top_c: c,
                first_round: c.min(3),
                max_patterns: c.min(3) + 1,
                ..Default::default()
            };
            let sol = match search(
                "tdfir",
                &prog,
                &an,
                &cfg,
                &XEON_BRONZE_3104,
                &ARRIA10_GX,
            ) {
                Ok(s) => s,
                Err(_) => continue,
            };
            let ratio = sol.speedup() / oracle;
            table.row(&[
                a.to_string(),
                c.to_string(),
                sol.measurements.len().to_string(),
                format!("{:.2}x", sol.speedup()),
                format!("{:.0}%", ratio * 100.0),
                if ratio > 0.99 { "yes" } else { "no" }.into(),
            ]);
            results.push(Json::Arr(vec![
                Json::Num(a as f64),
                Json::Num(c as f64),
                Json::Num(sol.speedup()),
            ]));
        }
    }
    table.print();

    // The paper's setting must hit the oracle.
    let paper = search(
        "tdfir",
        &prog,
        &an,
        &SearchConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    )
    .unwrap();
    assert!(
        paper.speedup() >= oracle * 0.99,
        "A=5/C=3 must find the single-loop oracle: {:.2} vs {:.2}",
        paper.speedup(),
        oracle
    );
    println!("\nshape check: PASS (A=5, C=3 reaches the oracle)");
    save_results("narrowing", &Json::Arr(results));
}
