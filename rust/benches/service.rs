//! Service-tier latency and throughput (ISSUE 7 acceptance): warm
//! cache-hit p50/p99 vs cold solve, sustained req/s at a fixed hit
//! ratio, and the no-starvation guarantee — a flood of cold solves must
//! not move cached-lookup p99, while the bounded queue rejects the
//! overload with typed admission errors.
//!
//! Writes `target/bench-results/BENCH_service.json`.

use std::sync::Arc;
use std::time::Instant;

use fpga_offload::service::{
    BackendKind, PlanRequest, Service, ServiceConfig,
};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::util::tempdir::TempDir;
use fpga_offload::workloads;

/// Fast synthetic source for flood traffic. Cold requests vary the
/// source text (trailing newlines via [`flood_source`]) because the
/// reuse key is app-name-blind: identical sources would coalesce onto
/// one in-flight solve instead of loading the queue.
const FLOOD_SRC: &str = "
#define N 512
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.002 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

fn quantiles(samples: &mut [u64]) -> (u64, u64) {
    samples.sort_unstable();
    let idx = |q: f64| {
        let rank = ((samples.len() as f64) * q).ceil() as usize;
        samples[rank.clamp(1, samples.len()) - 1]
    };
    (idx(0.50), idx(0.99))
}

fn plan_for(app: &str) -> PlanRequest {
    match workloads::source(app) {
        Some(src) => PlanRequest::new(app, src),
        None => PlanRequest::new(app, FLOOD_SRC),
    }
}

/// `FLOOD_SRC` with a unique source fingerprint per `n` (same program,
/// `n + 1` trailing newlines) — a genuinely distinct cold solve.
fn flood_source(n: usize) -> String {
    format!("{FLOOD_SRC}{}", "\n".repeat(n + 1))
}

fn main() {
    let dir = TempDir::new("bench-service").unwrap();
    // Queue deliberately smaller than the flood below (16 blocking
    // producers vs 2 workers + 8 slots), so admission control must
    // trip.
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 2,
        queue_cap: 8,
        backend: BackendKind::Fpga,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(Service::start(cfg).unwrap());

    // --- Cold solves: every bundled app once, timed individually.
    let mut cold_us: Vec<u64> = Vec::new();
    for app in workloads::APPS {
        let t0 = Instant::now();
        let resp = svc.request(plan_for(app));
        assert!(resp.ok(), "{app} cold solve failed: {:?}", resp.result);
        assert!(!resp.is_hit(), "{app} unexpectedly warm");
        cold_us.push(t0.elapsed().as_micros() as u64);
    }
    let (cold_p50, cold_p99) = quantiles(&mut cold_us);

    // --- Warm hits: the same apps served from the in-memory index.
    let mut warm_us: Vec<u64> = Vec::new();
    for _ in 0..200 {
        for app in workloads::APPS {
            let t0 = Instant::now();
            let resp = svc.request(plan_for(app));
            assert!(resp.is_hit(), "{app} should hit: {:?}", resp.result);
            warm_us.push(t0.elapsed().as_micros() as u64);
        }
    }
    let (warm_p50, warm_p99) = quantiles(&mut warm_us);

    // --- Sustained mixed traffic at a fixed ~90/10 hit ratio.
    let mixed_t0 = Instant::now();
    let mut mixed_served = 0u64;
    let mut cold_seq = 0u64;
    const MIXED_TOTAL: u64 = 200;
    for i in 0..MIXED_TOTAL {
        let resp = if i % 10 == 9 {
            cold_seq += 1;
            svc.request(PlanRequest::new(
                format!("mixed_cold_{cold_seq}"),
                flood_source(cold_seq as usize),
            ))
        } else {
            let app = workloads::APPS[(i as usize) % workloads::APPS.len()];
            svc.request(plan_for(app))
        };
        if resp.ok() {
            mixed_served += 1;
        }
    }
    let mixed_s = mixed_t0.elapsed().as_secs_f64();
    let mixed_rps = mixed_served as f64 / mixed_s.max(1e-9);
    assert_eq!(mixed_served, MIXED_TOTAL, "mixed traffic dropped requests");

    // --- Starvation check: flood the queue with cold solves from
    // background threads while timing cached lookups from the caller
    // side. Hits bypass the queue, so their p99 must stay bounded even
    // with the queue saturated and rejecting.
    let flood_threads: Vec<_> = (0..16)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                let mut rejected = 0u64;
                for i in 0..8u64 {
                    let mut req = PlanRequest::new(
                        format!("flood_{t}_{i}"),
                        flood_source(100 + (t * 8 + i) as usize),
                    );
                    // Bounded patience so a saturated pool cannot wedge
                    // the bench; rejects come back in microseconds and
                    // the thread immediately offers the next request.
                    req.deadline_ms = Some(10_000);
                    let resp = svc.request(req);
                    if resp.is_rejected() {
                        rejected += 1;
                        assert!(
                            resp.retry_after_ms.is_some(),
                            "reject without a retry hint"
                        );
                    }
                }
                rejected
            })
        })
        .collect();
    let mut flood_hit_us: Vec<u64> = Vec::new();
    for _ in 0..100 {
        for app in workloads::APPS {
            let t0 = Instant::now();
            let resp = svc.request(plan_for(app));
            assert!(
                resp.is_hit(),
                "hit starved during flood: {:?}",
                resp.result
            );
            flood_hit_us.push(t0.elapsed().as_micros() as u64);
        }
    }
    let rejected: u64 =
        flood_threads.into_iter().map(|h| h.join().unwrap()).sum();
    let (flood_hit_p50, flood_hit_p99) = quantiles(&mut flood_hit_us);

    let snap = svc.stats();
    svc.shutdown();

    let mut table = Table::new(&["series", "p50", "p99", "note"]);
    table.row(&[
        "cold solve".into(),
        format!("{:.1} ms", cold_p50 as f64 / 1e3),
        format!("{:.1} ms", cold_p99 as f64 / 1e3),
        format!("{} bundled apps", workloads::APPS.len()),
    ]);
    table.row(&[
        "warm hit".into(),
        format!("{warm_p50} us"),
        format!("{warm_p99} us"),
        format!("{} lookups", 200 * workloads::APPS.len()),
    ]);
    table.row(&[
        "hit under flood".into(),
        format!("{flood_hit_p50} us"),
        format!("{flood_hit_p99} us"),
        format!("{rejected} flood rejects"),
    ]);
    table.row(&[
        "mixed 90/10".into(),
        format!("{mixed_rps:.0} req/s"),
        "-".into(),
        format!("{MIXED_TOTAL} requests"),
    ]);
    table.print();

    // Acceptance: a warm hit is >= 100x faster than a cold solve at p50,
    // and the flood cannot starve cached lookups.
    assert!(
        warm_p50.max(1) * 100 <= cold_p50,
        "hit p50 {warm_p50}us not 100x faster than cold p50 {cold_p50}us"
    );
    assert!(
        flood_hit_p99 <= 50_000,
        "cached-lookup p99 {flood_hit_p99}us unbounded under flood"
    );
    assert!(
        rejected > 0,
        "flood never tripped admission control (queue too large \
         for the workload?)"
    );

    save_results(
        "BENCH_service",
        &Json::obj(vec![
            ("cold_p50_us", Json::Num(cold_p50 as f64)),
            ("cold_p99_us", Json::Num(cold_p99 as f64)),
            ("warm_hit_p50_us", Json::Num(warm_p50 as f64)),
            ("warm_hit_p99_us", Json::Num(warm_p99 as f64)),
            ("flood_hit_p50_us", Json::Num(flood_hit_p50 as f64)),
            ("flood_hit_p99_us", Json::Num(flood_hit_p99 as f64)),
            ("mixed_hit_ratio", Json::Num(0.9)),
            ("mixed_req_per_s", Json::Num(mixed_rps)),
            (
                "hit_speedup_vs_cold_p50",
                Json::Num(cold_p50 as f64 / warm_p50.max(1) as f64),
            ),
            ("flood_rejected", Json::Num(rejected as f64)),
            ("served_hits", Json::Num(snap.hits as f64)),
            ("served_misses", Json::Num(snap.misses as f64)),
            ("coalesced", Json::Num(snap.coalesced as f64)),
            ("timeouts", Json::Num(snap.timeouts as f64)),
            ("avg_solve_ms", Json::Num(snap.avg_solve_ms)),
        ]),
    );
    println!("series recorded: target/bench-results/BENCH_service.json");
    println!("service bench PASS");
}
