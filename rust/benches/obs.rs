//! Tracing overhead on the service hot path (ISSUE 9 acceptance): the
//! same warm cache-hit workload served by a traced service and by one
//! started with tracing disabled, interleaved batch-by-batch so clock
//! drift and cache warmth hit both sides equally. The traced median
//! must stay within 3% of the untraced one.
//!
//! Writes `target/bench-results/BENCH_obs.json`.

use std::time::Instant;

use fpga_offload::obs::TraceConfig;
use fpga_offload::service::{PlanRequest, Service, ServiceConfig};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::util::tempdir::TempDir;

/// Fast two-loop source; one cold solve warms it, then every request
/// is an index hit — the latency-critical path tracing must not tax.
const HOT: &str = "
#define N 512
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.002 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

/// Interleaved A/B rounds; odd so the median is a single sample.
const ROUNDS: usize = 21;
/// Warm hits per timed batch.
const BATCH: usize = 500;

fn service(dir: &TempDir, traced: bool) -> Service {
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        trace: TraceConfig {
            enabled: traced,
            ..TraceConfig::default()
        },
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg).unwrap();
    let warm = svc.request(PlanRequest::new("hot", HOT));
    assert!(warm.ok(), "warmup solve failed: {:?}", warm.result);
    svc
}

/// One timed batch of warm hits, nanoseconds.
fn batch_ns(svc: &Service) -> u64 {
    let t0 = Instant::now();
    for _ in 0..BATCH {
        let resp = svc.request(PlanRequest::new("hot", HOT));
        assert!(resp.is_hit(), "hot path went cold: {:?}", resp.result);
    }
    t0.elapsed().as_nanos() as u64
}

fn median(samples: &mut [u64]) -> u64 {
    samples.sort_unstable();
    samples[samples.len() / 2]
}

fn main() {
    let dir_traced = TempDir::new("bench-obs-traced").unwrap();
    let dir_plain = TempDir::new("bench-obs-plain").unwrap();
    let traced = service(&dir_traced, true);
    let plain = service(&dir_plain, false);

    // Untimed warmup round for both sides.
    batch_ns(&traced);
    batch_ns(&plain);

    let mut traced_ns = Vec::with_capacity(ROUNDS);
    let mut plain_ns = Vec::with_capacity(ROUNDS);
    for _ in 0..ROUNDS {
        traced_ns.push(batch_ns(&traced));
        plain_ns.push(batch_ns(&plain));
    }
    let med_traced = median(&mut traced_ns);
    let med_plain = median(&mut plain_ns);
    let per_hit_traced = med_traced as f64 / BATCH as f64 / 1e3;
    let per_hit_plain = med_plain as f64 / BATCH as f64 / 1e3;
    let overhead_pct =
        (med_traced as f64 / med_plain as f64 - 1.0) * 100.0;

    let recorded = traced.tracer().recorded();
    let dropped = traced.tracer().dropped();
    traced.shutdown();
    plain.shutdown();

    let mut table = Table::new(&["series", "per hit", "batch median"]);
    table.row(&[
        "traced".into(),
        format!("{per_hit_traced:.2} us"),
        format!("{:.2} ms", med_traced as f64 / 1e6),
    ]);
    table.row(&[
        "no-trace".into(),
        format!("{per_hit_plain:.2} us"),
        format!("{:.2} ms", med_plain as f64 / 1e6),
    ]);
    table.row(&[
        "overhead".into(),
        format!("{overhead_pct:+.2} %"),
        format!("{recorded} spans, {dropped} dropped"),
    ]);
    table.print();

    // Acceptance: tracing costs < 3% on the hot path. The tracer was
    // genuinely on — it recorded spans (the bounded ring dropping the
    // backlog is fine; dropping must be what keeps it cheap).
    assert!(recorded > 0, "traced service recorded no spans");
    assert!(
        overhead_pct < 3.0,
        "tracing overhead {overhead_pct:.2}% exceeds the 3% budget \
         (traced {per_hit_traced:.2}us vs plain {per_hit_plain:.2}us \
         per hit)"
    );

    save_results(
        "BENCH_obs",
        &Json::obj(vec![
            ("batch_size", Json::Num(BATCH as f64)),
            ("rounds", Json::Num(ROUNDS as f64)),
            ("traced_hit_us", Json::Num(per_hit_traced)),
            ("untraced_hit_us", Json::Num(per_hit_plain)),
            ("overhead_pct", Json::Num(overhead_pct)),
            ("bound_pct", Json::Num(3.0)),
            ("spans_recorded", Json::Num(recorded as f64)),
            ("spans_dropped", Json::Num(dropped as f64)),
        ]),
    );
    println!("series recorded: target/bench-results/BENCH_obs.json");
    println!("obs bench PASS");
}
