//! Sharded pattern-store benchmarks (ISSUE 8 acceptance): cold open
//! (full shard-log replay) and warm open (shared process handle) at
//! 10k+ plans vs the legacy flat-file scan, 16-thread mixed read/write
//! throughput vs a flat-file baseline, and a small kill-point recovery
//! sweep.
//!
//! Writes `target/bench-results/BENCH_patterndb.json`.
//!
//! Acceptance asserted here: warm open >= 10x faster than the legacy
//! flat scan, and zero records lost across the kill points.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use fpga_offload::store::{log, PatternStore};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::util::tempdir::TempDir;

const RECORDS: usize = 10_000;
const THREADS: usize = 16;
const OPS_PER_THREAD: usize = 2_000;

fn app_name(i: usize) -> String {
    format!("app-{i:05}")
}

fn record_payload(i: usize, stamp: u64) -> Vec<u8> {
    format!(
        r#"{{"app":"{}","speedup":{:.2},"automation_hours":{:.2},"stored_at":"{}"}}"#,
        app_name(i),
        1.0 + (i % 17) as f64 * 0.25,
        2.0 + (i % 11) as f64,
        stamp
    )
    .into_bytes()
}

/// Populate the sharded store: bucket the payloads per shard and write
/// each shard log atomically, exactly as compaction does.
fn populate(dir: &Path, stamp: u64) {
    let store = PatternStore::open_fresh(dir).unwrap();
    let mut by_shard: Vec<(std::path::PathBuf, Vec<Vec<u8>>)> = Vec::new();
    for i in 0..RECORDS {
        let path = store.shard_path_of(&app_name(i));
        let payload = record_payload(i, stamp);
        match by_shard.iter_mut().find(|(p, _)| *p == path) {
            Some((_, v)) => v.push(payload),
            None => by_shard.push((path, vec![payload])),
        }
    }
    drop(store);
    for (path, payloads) in &by_shard {
        let refs: Vec<&[u8]> =
            payloads.iter().map(Vec::as_slice).collect();
        log::write_atomic(path, &refs).unwrap();
    }
}

/// Cheap deterministic per-thread RNG (no external crates).
fn lcg(state: &mut u64) -> u64 {
    *state = state
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407);
    *state >> 33
}

fn now_secs() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_secs()
}

fn main() {
    let dir = TempDir::new("bench-patterndb").unwrap();
    let stamp = now_secs();
    populate(dir.path(), stamp);

    // --- Cold open: replay all 16 shard logs into the in-memory index.
    let t0 = Instant::now();
    let store = PatternStore::open_fresh(dir.path()).unwrap();
    let cold_open_us = t0.elapsed().as_micros() as u64;
    assert_eq!(store.len(), RECORDS, "cold open lost records");
    drop(store);

    // --- Legacy baseline: the flat one-file-per-app layout the store
    // replaced, seeded from the same records, scanned the way the old
    // `PatternIndex::open` did (read + parse every file).
    let legacy_dir = TempDir::new("bench-patterndb-legacy").unwrap();
    let store = PatternStore::open(dir.path()).unwrap();
    let exported = store.export_legacy(legacy_dir.path()).unwrap();
    assert_eq!(exported, RECORDS);
    let t0 = Instant::now();
    let legacy = PatternStore::scan_legacy(legacy_dir.path()).unwrap();
    let legacy_scan_us = t0.elapsed().as_micros() as u64;
    assert_eq!(legacy.len(), RECORDS, "legacy scan lost records");

    // --- Warm open: the process already holds the handle; open() is a
    // registry lookup, not a replay. Timed over many opens for a
    // measurable duration.
    const WARM_OPENS: u32 = 1_000;
    let t0 = Instant::now();
    for _ in 0..WARM_OPENS {
        let s = PatternStore::open(dir.path()).unwrap();
        assert_eq!(s.len(), RECORDS);
    }
    let warm_open_us =
        (t0.elapsed().as_micros() as u64).max(1) / WARM_OPENS as u64;

    // --- 16-thread mixed traffic, ~90% reads / 10% writes, against the
    // sharded store (reads take only a shard index read lock).
    let store = Arc::new(store);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                let mut rng = 0x9e3779b97f4a7c15u64 ^ (t as u64) << 17;
                for _ in 0..OPS_PER_THREAD {
                    let i = (lcg(&mut rng) as usize) % RECORDS;
                    let app = app_name(i);
                    if lcg(&mut rng) % 10 == 0 {
                        store
                            .restamp(&app, stamp + lcg(&mut rng) % 1000)
                            .unwrap();
                    } else {
                        assert!(store.get(&app).is_some());
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let store_mixed_s = t0.elapsed().as_secs_f64();
    let total_ops = (THREADS * OPS_PER_THREAD) as f64;
    let store_ops_s = total_ops / store_mixed_s.max(1e-9);

    // --- The same mixed traffic against the flat-file layout: every
    // read is an open+parse, every write a whole-file rewrite.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let dir = legacy_dir.path().to_path_buf();
            std::thread::spawn(move || {
                let mut rng = 0x51afb0c1e97d3e21u64 ^ (t as u64) << 13;
                for _ in 0..OPS_PER_THREAD {
                    let i = (lcg(&mut rng) as usize) % RECORDS;
                    let path =
                        dir.join(format!("{}.pattern.json", app_name(i)));
                    if lcg(&mut rng) % 10 == 0 {
                        std::fs::write(
                            &path,
                            String::from_utf8(record_payload(
                                i,
                                stamp + lcg(&mut rng) % 1000,
                            ))
                            .unwrap(),
                        )
                        .unwrap();
                    } else {
                        let text =
                            std::fs::read_to_string(&path).unwrap();
                        assert!(Json::parse(&text).is_ok());
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let flat_mixed_s = t0.elapsed().as_secs_f64();
    let flat_ops_s = total_ops / flat_mixed_s.max(1e-9);

    // --- Kill-point sweep: tear the tail of one shard log at a few
    // byte offsets; every prior record must survive recovery.
    let kill_dir = TempDir::new("bench-patterndb-kill").unwrap();
    populate(kill_dir.path(), stamp);
    let victim = {
        let s = PatternStore::open_fresh(kill_dir.path()).unwrap();
        s.shard_path_of(&app_name(0))
    };
    let full = std::fs::read(&victim).unwrap();
    let mut kill_points = 0u64;
    let mut recover_us: Vec<u64> = Vec::new();
    for cut_back in [1usize, 5, 11, 12, 20] {
        std::fs::write(&victim, &full[..full.len() - cut_back]).unwrap();
        let t0 = Instant::now();
        let s = PatternStore::open_fresh(kill_dir.path()).unwrap();
        recover_us.push(t0.elapsed().as_micros() as u64);
        // Exactly the torn final record is gone; nothing else.
        assert_eq!(s.len(), RECORDS - 1, "kill point lost extra records");
        assert!(s.quarantined().unwrap().is_empty());
        kill_points += 1;
        std::fs::write(&victim, &full).unwrap();
    }
    let recover_p_max = *recover_us.iter().max().unwrap();

    let warm_speedup =
        legacy_scan_us as f64 / warm_open_us.max(1) as f64;
    let mut table = Table::new(&["series", "value", "note"]);
    table.row(&[
        "cold open (replay)".into(),
        format!("{:.1} ms", cold_open_us as f64 / 1e3),
        format!("{RECORDS} records, 16 shards"),
    ]);
    table.row(&[
        "legacy flat scan".into(),
        format!("{:.1} ms", legacy_scan_us as f64 / 1e3),
        format!("{RECORDS} files"),
    ]);
    table.row(&[
        "warm open (shared handle)".into(),
        format!("{warm_open_us} us"),
        format!("{warm_speedup:.0}x vs flat scan"),
    ]);
    table.row(&[
        "mixed 90/10 sharded".into(),
        format!("{store_ops_s:.0} ops/s"),
        format!("{THREADS} threads"),
    ]);
    table.row(&[
        "mixed 90/10 flat files".into(),
        format!("{flat_ops_s:.0} ops/s"),
        format!("{THREADS} threads"),
    ]);
    table.row(&[
        "kill-point recovery".into(),
        format!("{recover_p_max} us max"),
        format!("{kill_points} kill points, 0 lost"),
    ]);
    table.print();

    // Acceptance: warm open >= 10x faster than the legacy flat scan.
    assert!(
        warm_speedup >= 10.0,
        "warm open {warm_open_us}us not 10x faster than legacy scan \
         {legacy_scan_us}us"
    );

    save_results(
        "BENCH_patterndb",
        &Json::obj(vec![
            ("records", Json::Num(RECORDS as f64)),
            ("shards", Json::Num(16.0)),
            ("cold_open_us", Json::Num(cold_open_us as f64)),
            ("legacy_scan_us", Json::Num(legacy_scan_us as f64)),
            ("warm_open_us", Json::Num(warm_open_us as f64)),
            ("warm_open_speedup_vs_flat", Json::Num(warm_speedup)),
            ("mixed_threads", Json::Num(THREADS as f64)),
            ("mixed_write_ratio", Json::Num(0.1)),
            ("store_mixed_ops_per_s", Json::Num(store_ops_s)),
            ("flat_mixed_ops_per_s", Json::Num(flat_ops_s)),
            (
                "mixed_speedup_vs_flat",
                Json::Num(store_ops_s / flat_ops_s.max(1e-9)),
            ),
            ("kill_points", Json::Num(kill_points as f64)),
            ("kill_recover_max_us", Json::Num(recover_p_max as f64)),
            ("kill_records_lost", Json::Num(0.0)),
        ]),
    );
    println!(
        "series recorded: target/bench-results/BENCH_patterndb.json"
    );
    println!("patterndb bench PASS");
}
