//! Comparison: the paper's narrowing funnel vs the previous work's GA
//! search [32] (§3.2: "code compiling to FPGA takes several hours … and
//! performance measurements of many patterns like [32] are difficult").
//!
//! Reports measurements-to-solution and the modeled compile wall-clock of
//! both strategies on both applications.

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{ga, search, GaConfig, SearchConfig};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!("== funnel vs GA baseline [32] ==\n");
    let mut table = Table::new(&[
        "application",
        "strategy",
        "best",
        "speedup",
        "measurements",
        "compile wall-clock h",
    ]);
    let mut results = Vec::new();

    for (app, src) in [
        ("tdfir", workloads::TDFIR_C),
        ("mriq", workloads::MRIQ_C),
    ] {
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();

        let sol = search(
            app,
            &prog,
            &an,
            &SearchConfig::default(),
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        )
        .unwrap();
        let ga_res = ga::run(
            &prog,
            &an,
            &GaConfig::default(),
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        );

        table.row(&[
            app.into(),
            "funnel".into(),
            sol.best_measurement().label(),
            format!("{:.2}x", sol.speedup()),
            sol.measurements.len().to_string(),
            format!("{:.0}", sol.automation_s / 3600.0),
        ]);
        table.row(&[
            app.into(),
            "GA [32]".into(),
            ga_res
                .best_loops
                .iter()
                .map(|l| l.to_string())
                .collect::<Vec<_>>()
                .join("+"),
            format!("{:.2}x", ga_res.best_speedup),
            ga_res.measurements.to_string(),
            format!("{:.0}", ga_res.modeled_wall_clock_s / 3600.0),
        ]);

        // Shape: the funnel reaches ≥80% of GA quality with far fewer
        // measured patterns (the paper's entire premise).
        assert!(sol.measurements.len() * 3 < ga_res.measurements.max(1));
        assert!(sol.speedup() >= ga_res.best_speedup * 0.8);

        results.push(Json::obj(vec![
            ("app", Json::Str(app.into())),
            ("funnel_speedup", Json::Num(sol.speedup())),
            (
                "funnel_measurements",
                Json::Num(sol.measurements.len() as f64),
            ),
            ("ga_speedup", Json::Num(ga_res.best_speedup)),
            ("ga_measurements", Json::Num(ga_res.measurements as f64)),
        ]));
    }
    table.print();
    println!("\nshape check: PASS (funnel ≪ GA measurements at comparable quality)");
    save_results("ga_vs_funnel", &Json::Arr(results));
}
