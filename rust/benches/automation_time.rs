//! §5.2 text reproduction: "it takes about half day to automatically
//! verifications of 4 patterns because it takes about 3 hours to compile
//! one offload pattern."
//!
//! The verification environment's wall clock is modeled (LPT scheduling
//! over the build-machine pool); this bench reproduces the half-day figure
//! and sweeps the pool size the paper's single machine forces to 1.

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{search, SearchConfig};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!("== §5.2: automation time (modeled FPGA compiles) ==\n");

    let mut table = Table::new(&[
        "application",
        "machines",
        "patterns",
        "mean compile h",
        "automation h",
        "paper",
    ]);
    let mut results = Vec::new();

    for (app, src) in [
        ("tdfir", workloads::TDFIR_C),
        ("mriq", workloads::MRIQ_C),
    ] {
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        for machines in [1usize, 2, 4] {
            let cfg = SearchConfig {
                build_machines: machines,
                ..Default::default()
            };
            let sol =
                search(app, &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX)
                    .unwrap();
            let mean_compile_h = sol
                .measurements
                .iter()
                .map(|m| m.compile_s)
                .sum::<f64>()
                / sol.measurements.len().max(1) as f64
                / 3600.0;
            let hours = sol.automation_s / 3600.0;
            table.row(&[
                app.into(),
                machines.to_string(),
                sol.measurements.len().to_string(),
                format!("{mean_compile_h:.1}"),
                format!("{hours:.1}"),
                if machines == 1 { "~12 h (half day)" } else { "-" }.into(),
            ]);
            if machines == 1 {
                // Paper ballpark: ~3 h per compile, patterns ≤ 4, so the
                // single-machine automation lands in 6–14 h.
                assert!(
                    (2.0..4.0).contains(&mean_compile_h),
                    "{app}: compile time {mean_compile_h:.1} h should be ~3 h"
                );
                assert!(
                    (5.0..15.0).contains(&hours),
                    "{app}: automation {hours:.1} h should be roughly half a day"
                );
            }
            results.push(Json::Arr(vec![
                Json::Str(app.into()),
                Json::Num(machines as f64),
                Json::Num(hours),
            ]));
        }
    }
    table.print();
    println!("\nshape check: PASS (~3 h/compile, single machine ≈ half day)");
    save_results("automation_time", &Json::Arr(results));
}
