//! Fig. 2 reproduction: the narrowing funnel's stage sizes and per-stage
//! cost for both applications.
//!
//! Paper §5.1.2: 36 (tdfir) / 16 (MRI-Q) loops → top-5 arithmetic
//! intensity → top-3 resource efficiency → ≤4 measured patterns. The cheap
//! stages (profiling, pre-compiles) run in milliseconds here; the
//! expensive stage (measured patterns) is what the funnel minimizes.

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{funnel, search, SearchConfig};
use fpga_offload::util::bench::{bench, save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!("== Fig. 2: narrowing funnel stages ==\n");
    let cfg = SearchConfig::default();
    let mut table = Table::new(&[
        "application",
        "loops",
        "offloadable",
        "top-A",
        "top-C",
        "measured",
        "paper loops",
    ]);
    let mut out = Vec::new();

    for (app, src, paper_loops) in [
        ("tdfir", workloads::TDFIR_C, 36.0),
        ("mriq", workloads::MRIQ_C, 16.0),
    ] {
        let prog = parse(src).unwrap();

        // Stage timings.
        bench(&format!("funnel/parse/{app}"), 1, 10, || {
            let _ = parse(src).unwrap();
        });
        let mut an = None;
        bench(&format!("funnel/profile/{app}"), 0, 3, || {
            an = Some(analyze(&prog, "main").unwrap());
        });
        let an = an.unwrap();
        bench(&format!("funnel/narrow/{app}"), 1, 10, || {
            let _ = funnel::run(&prog, &an, &cfg, &ARRIA10_GX).unwrap();
        });

        let (_, trace) = funnel::run(&prog, &an, &cfg, &ARRIA10_GX).unwrap();
        let sol = search(app, &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX)
            .unwrap();

        assert_eq!(trace.total_loops as f64, paper_loops, "{app} loop count");
        assert!(trace.top_a.len() <= cfg.top_a);
        assert!(trace.top_c.len() <= cfg.top_c);
        assert!(sol.measurements.len() <= cfg.max_patterns);

        table.row(&[
            app.into(),
            trace.total_loops.to_string(),
            trace.offloadable.len().to_string(),
            trace.top_a.len().to_string(),
            trace.top_c.len().to_string(),
            sol.measurements.len().to_string(),
            format!("{paper_loops}"),
        ]);
        out.push((
            app,
            Json::Arr(vec![
                Json::Num(trace.total_loops as f64),
                Json::Num(trace.top_a.len() as f64),
                Json::Num(trace.top_c.len() as f64),
                Json::Num(sol.measurements.len() as f64),
            ]),
        ));
    }

    println!();
    table.print();
    println!("\nshape check: PASS (36/16 loops, ≤5 → ≤3 → ≤4 funnel)");
    save_results("funnel", &Json::obj(out));
}
