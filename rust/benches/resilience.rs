//! Resilience-layer cost and recovery: what does the retry seam cost a
//! fault-free cycle, and how fast does a faulted cycle recover?
//!
//! Two figures, recorded as the `BENCH_resilience.json` series
//! (target/bench-results/):
//!
//! * **Fault-free overhead** — wall-clock of a retry-wrapped mixed
//!   batch over an unwrapped one, no faults injected (best-of-K, so
//!   scheduler noise cancels). The wrapper adds a closure call and a
//!   few atomic counters per measurement; the budget is < 2%.
//! * **Time-to-recovery** — a seeded transient fault plan on every
//!   destination: virtual backoff seconds and retry counts spent before
//!   the cycle completes at full service with the fault-free plan.

use std::time::Instant;

use fpga_offload::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use fpga_offload::envadapt::{
    Batch, OffloadRequest, Pipeline, ServiceLevel, TestDb,
};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    Backend, CpuBaseline, FaultPlan, FaultyBackend, FpgaBackend,
    GpuBackend, OmpBackend, RetryPolicy, SearchConfig, SimClock,
};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

const TIMING_ROUNDS: usize = 5;

fn requests() -> Vec<OffloadRequest> {
    let testdb = TestDb::builtin();
    workloads::APPS
        .iter()
        .map(|app| {
            let case = testdb.get(app).expect("registered");
            let mut req = OffloadRequest::from_case(
                case,
                workloads::source(app).unwrap(),
            );
            req.pjrt_sample = None;
            req
        })
        .collect()
}

fn run_mixed(pipelines: Vec<&Pipeline>) -> fpga_offload::envadapt::BatchReport {
    let mut batch = Batch::mixed(pipelines);
    for req in requests() {
        batch.push(req);
    }
    batch.run()
}

/// Best-of-K wall clock of one mixed cycle over the given pipelines.
fn best_wall_clock_s(pipelines: &[&Pipeline]) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..TIMING_ROUNDS {
        let start = Instant::now();
        let report = run_mixed(pipelines.to_vec());
        let dt = start.elapsed().as_secs_f64();
        assert_eq!(report.solved(), report.entries.len());
        best = best.min(dt);
    }
    best
}

fn main() {
    println!("== resilience: fault-free overhead + time-to-recovery ==\n");

    let fpga = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let gpu = GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    };
    let omp = OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    };
    let cpu = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let backends: [&dyn Backend; 4] = [&fpga, &gpu, &omp, &cpu];
    let cfg = SearchConfig::default();

    // --- Fault-free overhead -------------------------------------------
    let plain: Vec<Pipeline> = backends
        .iter()
        .map(|&b| Pipeline::new(cfg.clone(), b).expect("pipeline"))
        .collect();
    let clock = SimClock::new();
    let wrapped: Vec<Pipeline> = backends
        .iter()
        .map(|&b| {
            Pipeline::new(cfg.clone(), b)
                .expect("pipeline")
                .with_retry(RetryPolicy::default())
                .expect("valid policy")
                .with_clock(clock.clone())
        })
        .collect();

    // Identical results first (one run each), then timing.
    let plain_report = run_mixed(plain.iter().collect());
    let wrapped_report = run_mixed(wrapped.iter().collect());
    assert_eq!(
        plain_report.to_json().get(&["results"]),
        wrapped_report.to_json().get(&["results"]),
        "retry wrapping must not change fault-free results"
    );
    assert_eq!(wrapped_report.fault_telemetry.total_retries(), 0);

    let plain_s = best_wall_clock_s(&plain.iter().collect::<Vec<_>>());
    let wrapped_s = best_wall_clock_s(&wrapped.iter().collect::<Vec<_>>());
    let overhead_pct = (wrapped_s / plain_s - 1.0) * 100.0;

    let mut table =
        Table::new(&["cycle", "wall clock (best of 5)", "overhead"]);
    table.row(&[
        "plain".into(),
        format!("{:.3} s", plain_s),
        "-".into(),
    ]);
    table.row(&[
        "retry-wrapped".into(),
        format!("{:.3} s", wrapped_s),
        format!("{overhead_pct:+.2}%"),
    ]);
    table.print();

    assert!(
        overhead_pct < 2.0,
        "fault-free retry overhead {overhead_pct:.2}% exceeds the 2% budget"
    );

    // --- Time-to-recovery under a seeded transient plan ----------------
    let fault_clock = SimClock::new();
    let faulty: Vec<FaultyBackend> = backends
        .iter()
        .map(|&b| {
            FaultyBackend::new(
                b,
                FaultPlan::transient_only(2020),
                fault_clock.clone(),
            )
        })
        .collect();
    let resilient: Vec<Pipeline> = faulty
        .iter()
        .map(|b| {
            Pipeline::new(cfg.clone(), b)
                .expect("pipeline")
                .with_retry(RetryPolicy::default())
                .expect("valid policy")
                .with_clock(fault_clock.clone())
        })
        .collect();
    let start = Instant::now();
    let faulted_report = run_mixed(resilient.iter().collect());
    let recovery_wall_s = start.elapsed().as_secs_f64();

    assert_eq!(
        faulted_report.solved(),
        faulted_report.entries.len(),
        "transient-only faults must all recover"
    );
    for e in &faulted_report.entries {
        assert_eq!(e.service, ServiceLevel::Full, "{} degraded", e.app);
    }
    assert_eq!(
        faulted_report.to_json().get(&["results"]),
        plain_report.to_json().get(&["results"]),
        "recovered cycle must match the fault-free plan"
    );
    let t = &faulted_report.fault_telemetry;
    let retries = t.total_retries();
    assert!(retries > 0, "the seeded plan injected nothing");
    let virtual_backoff_s = fault_clock.now_s();
    println!(
        "\nrecovery: {} retries, {:.0} virtual seconds of backoff \
         ({:.1} virtual h), identical plans, {:.3} s wall clock",
        retries,
        virtual_backoff_s,
        virtual_backoff_s / 3600.0,
        recovery_wall_s,
    );

    save_results(
        "BENCH_resilience",
        &Json::obj(vec![
            ("plain_wall_s", Json::Num(plain_s)),
            ("wrapped_wall_s", Json::Num(wrapped_s)),
            ("fault_free_overhead_pct", Json::Num(overhead_pct)),
            ("recovery_retries", Json::Num(retries as f64)),
            ("recovery_virtual_backoff_s", Json::Num(virtual_backoff_s)),
            ("recovery_wall_s", Json::Num(recovery_wall_s)),
            ("fault_telemetry", t.to_json()),
            ("apps", Json::Num(faulted_report.entries.len() as f64)),
        ]),
    );
    println!("\nseries recorded: target/bench-results/BENCH_resilience.json");
    println!("resilience shape: PASS");
}
