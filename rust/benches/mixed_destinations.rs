//! Mixed-destination split: where does each bundled application land
//! when one automation cycle measures it against FPGA, GPU, many-core
//! OpenMP and CPU?
//!
//! Records the per-app destination and per-backend speedups as the
//! `BENCH_mixed.json` series (target/bench-results/), so the routing
//! trajectory is tracked across changes to any performance model.
//! Asserts only the *shape* the models are calibrated for: every app
//! routed, the control never beats a real destination, both accelerator
//! destinations win at least one bundled app, and the many-core
//! destination strictly beats the all-CPU control on at least one
//! (today it also wins sobel outright: the stencil's light per-pixel
//! work cannot amortize a PCIe crossing, but parallelizes cleanly over
//! shared memory — the per-app series records that routing).

use fpga_offload::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use fpga_offload::envadapt::{Batch, OffloadRequest, Pipeline, TestDb};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    CpuBaseline, FpgaBackend, GpuBackend, OmpBackend, SearchConfig,
};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!(
        "== mixed destinations: per-app routing across fpga/gpu/omp/cpu ==\n"
    );

    let fpga = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let gpu = GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    };
    let omp = OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    };
    let cpu = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let cfg = SearchConfig::default();
    let pf = Pipeline::new(cfg.clone(), &fpga).expect("fpga pipeline");
    let pg = Pipeline::new(cfg.clone(), &gpu).expect("gpu pipeline");
    let po = Pipeline::new(cfg.clone(), &omp).expect("omp pipeline");
    let pc = Pipeline::new(cfg, &cpu).expect("cpu pipeline");

    let testdb = TestDb::builtin();
    let mut batch = Batch::mixed(vec![&pf, &pg, &po, &pc]);
    for app in workloads::APPS {
        let case = testdb.get(app).expect("registered");
        let mut req =
            OffloadRequest::from_case(case, workloads::source(app).unwrap());
        req.pjrt_sample = None;
        batch.push(req);
    }
    let report = batch.run();

    let mut table = Table::new(&[
        "application",
        "destination",
        "fpga",
        "gpu",
        "omp",
        "cpu",
        "winner",
    ]);
    let mut apps_json = Vec::new();
    let mut best_omp = 0.0f64;
    for e in &report.entries {
        let plan = e.plan.as_ref().expect("every bundled app solves");
        let dest = e.destination.expect("every bundled app routed");
        let speedup_of = |backend: &str| -> f64 {
            e.outcomes
                .iter()
                .find(|o| o.backend == backend)
                .and_then(|o| o.plan.as_ref())
                .map(|p| p.speedup())
                .unwrap_or(0.0)
        };
        let (sf, sg, so, sc) = (
            speedup_of("fpga"),
            speedup_of("gpu"),
            speedup_of("omp"),
            speedup_of("cpu"),
        );
        best_omp = best_omp.max(so);
        table.row(&[
            e.app.clone(),
            dest.to_string(),
            format!("{sf:.2}x"),
            format!("{sg:.2}x"),
            format!("{so:.2}x"),
            format!("{sc:.2}x"),
            format!("{:.2}x", plan.speedup()),
        ]);
        apps_json.push(Json::obj(vec![
            ("app", Json::Str(e.app.clone())),
            ("destination", Json::Str(dest.to_string())),
            ("fpga_speedup", Json::Num(sf)),
            ("gpu_speedup", Json::Num(sg)),
            ("omp_speedup", Json::Num(so)),
            ("cpu_speedup", Json::Num(sc)),
            ("selected_speedup", Json::Num(plan.speedup())),
        ]));

        // Shape: the all-CPU control is exactly 1x and never wins a
        // routed app outright.
        assert!((sc - 1.0).abs() < 1e-9, "{}: cpu control {sc}", e.app);
        assert!(plan.speedup() >= 1.0, "{}: routed below 1x", e.app);
    }

    table.print();

    let counts = report.destination_counts();
    let split: Vec<String> = counts
        .iter()
        .map(|(b, n)| format!("{b} {n}"))
        .collect();
    println!("\ndestination split: {}", split.join(" / "));

    let count_of = |name: &str| -> usize {
        counts
            .iter()
            .find(|(b, _)| *b == name)
            .map(|(_, n)| *n)
            .unwrap_or(0)
    };
    assert!(
        count_of("fpga") >= 1,
        "mixed environment degenerated: no app on the FPGA"
    );
    assert!(
        count_of("gpu") >= 1,
        "mixed environment degenerated: no app on the GPU"
    );
    // The fourth destination must earn its seat: at minimum it strictly
    // beats the all-CPU control on some bundled app. (Today it also
    // wins sobel outright — tracked in the JSON series, not asserted,
    // so model recalibration can move the routing without breaking CI.)
    assert!(
        best_omp > 1.0,
        "omp never strictly beat the CPU baseline: {best_omp:.2}x"
    );

    let mut destinations = std::collections::BTreeMap::new();
    for (b, n) in &counts {
        destinations.insert(b.to_string(), Json::Num(*n as f64));
    }
    save_results(
        "BENCH_mixed",
        &Json::obj(vec![
            ("apps", Json::Arr(apps_json)),
            ("destinations", Json::Obj(destinations)),
            (
                "serial_automation_hours",
                Json::Num(report.serial_automation_s / 3600.0),
            ),
            (
                "concurrent_automation_hours",
                Json::Num(report.concurrent_automation_s / 3600.0),
            ),
        ]),
    );
    println!("\nseries recorded: target/bench-results/BENCH_mixed.json");
    println!("mixed-destination shape: PASS");
}
