//! Ablation: loop expansion factor B (paper §4: "the loop sentence is
//! expanded by number B … increases the amount of resources, but is
//! effective for speeding up"; §5.1.2 fixes B=1).
//!
//! Sweeps B on the tdfir hot loop and reports resources vs modeled
//! speedup — the resource/speed trade the paper describes, including the
//! diminishing returns as fmax derates with utilization.

use fpga_offload::analysis::analyze;
use fpga_offload::codegen::{split, unroll};
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::fpga::simulate;
use fpga_offload::hls::{estimate, ARRIA10_GX};
use fpga_offload::minic::ast::LoopId;
use fpga_offload::minic::parse;
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!("== ablation: expansion factor B on the tdfir hot loop ==\n");
    let prog = parse(workloads::TDFIR_C).unwrap();
    let an = analyze(&prog, "main").unwrap();

    // The hot repetition loop found by the funnel (L12).
    let al = an.loop_by_id(LoopId(12)).expect("tdfir hot loop");
    let base = split(&prog, al).expect("split");

    let mut table = Table::new(&[
        "B", "LUT %", "DSP %", "fits", "speedup",
    ]);
    let mut speedups = Vec::new();
    let mut results = Vec::new();
    for b in [1u32, 2, 4, 8, 16] {
        let k = match unroll(&base.kernel, b) {
            Ok(k) => k,
            Err(e) => {
                println!("B={b}: {e}");
                continue;
            }
        };
        let est = estimate(&k);
        let util = est.utilization(&ARRIA10_GX);
        let fits = est.fits(&ARRIA10_GX);
        let speedup = if fits {
            simulate(&an, &[k], &XEON_BRONZE_3104, &ARRIA10_GX)
                .map(|t| t.speedup)
                .unwrap_or(0.0)
        } else {
            0.0
        };
        table.row(&[
            b.to_string(),
            format!("{:.1}", util.luts * 100.0),
            format!("{:.1}", util.dsps * 100.0),
            fits.to_string(),
            if fits {
                format!("{speedup:.2}x")
            } else {
                "-".into()
            },
        ]);
        if fits {
            speedups.push((b, speedup, util.dsps));
        }
        results.push(Json::Arr(vec![
            Json::Num(b as f64),
            Json::Num(util.dsps),
            Json::Num(speedup),
        ]));
    }
    table.print();

    // Shape: resources grow monotonically with B. Speed is NOT required
    // to improve — the paper hedges exactly this ("Depending on the loop
    // statement, these may not have an absolute effect"): the tdfir hot
    // loop is already spatialized on its K-tap inner loop, so extra
    // expansion only burns DSPs and derates fmax. The assertion is that
    // expansion never *collapses* performance while the design fits.
    for w in speedups.windows(2) {
        assert!(
            w[1].2 > w[0].2,
            "DSP use must grow with B: {:?} -> {:?}",
            w[0],
            w[1]
        );
        assert!(
            w[1].1 >= speedups[0].1 * 0.6,
            "expansion should not collapse performance while fitting: {:?}",
            w[1]
        );
    }
    println!(
        "\nshape check: PASS (resources grow with B; speed within 40% of B=1 \
         — expansion unhelpful on an already-spatialized loop, as the paper \
         hedges)"
    );
    save_results("unroll_ablation", &Json::Arr(results));
}
