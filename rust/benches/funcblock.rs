//! Function-block vs loop-only speedups across the bundled workloads —
//! the ISSUE-4 acceptance series.
//!
//! For every app × {fpga, gpu} destination the staged pipeline runs
//! twice under the same seed: loop-only, and with the function-block
//! path enabled. Records `BENCH_funcblock.json`
//! (target/bench-results/) and asserts the acceptance shape:
//!
//! * at least one bundled app gets a **strictly** better verified
//!   speedup with blocks enabled than loop-only;
//! * blocks never make any app worse (unprofitable blocks are simply
//!   not planned);
//! * every accepted replacement is behaviorally confirmed.

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{OffloadRequest, Pipeline, TestDb};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    Backend, FpgaBackend, GpuBackend, SearchConfig,
};
use fpga_offload::util::bench::{save_results, Table};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn request(app: &str, func_blocks: bool) -> OffloadRequest {
    let testdb = TestDb::builtin();
    let case = testdb.get(app).expect("bundled app");
    let mut req =
        OffloadRequest::from_case(case, workloads::source(app).unwrap());
    req.pjrt_sample = None;
    req.with_func_blocks(func_blocks)
}

fn main() {
    println!("== function-block offloading vs loop-only ==\n");

    let fpga = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let gpu = GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    };
    let backends: [&dyn Backend; 2] = [&fpga, &gpu];

    let mut table = Table::new(&[
        "application",
        "backend",
        "loop-only",
        "with blocks",
        "blocks",
    ]);
    let mut rows_json = Vec::new();
    let mut strictly_better_anywhere = false;

    for app in workloads::APPS {
        for backend in backends {
            let pipe =
                Pipeline::new(SearchConfig::default(), backend)
                    .expect("valid config");
            let loop_only =
                pipe.solve(request(app, false)).expect("loop-only");
            let blocked =
                pipe.solve(request(app, true)).expect("func-blocks");

            assert!(loop_only.plan.verified_ok(), "{app}");
            assert!(blocked.plan.verified_ok(), "{app}");
            let sol = blocked.plan.solution().expect("fresh plan");
            for b in &sol.blocks {
                assert!(
                    b.confirmed,
                    "{app}: unconfirmed replacement {} reached the plan",
                    b.func
                );
            }

            let ls = loop_only.plan.speedup();
            let bs = blocked.plan.speedup();
            // Blocks must not regress an app: an unprofitable block is
            // not planned, and the blocks-only (empty loop pattern)
            // plan is always selectable. A hair of slack covers the
            // case where a claimed loop's auto-offload and its core
            // price within model noise of each other.
            assert!(
                bs >= ls * 0.999,
                "{app}@{}: blocks regressed {ls:.3}x -> {bs:.3}x",
                backend.name()
            );
            if backend.name() == "fpga" && bs > ls + 1e-9 {
                strictly_better_anywhere = true;
            }

            let kinds: Vec<String> = sol
                .blocks
                .iter()
                .map(|b| format!("{}:{}", b.func, b.kind))
                .collect();
            table.row(&[
                app.to_string(),
                backend.name().to_string(),
                format!("{ls:.2}x"),
                format!("{bs:.2}x"),
                if kinds.is_empty() {
                    "-".to_string()
                } else {
                    kinds.join(" ")
                },
            ]);
            rows_json.push(Json::obj(vec![
                ("app", Json::Str(app.to_string())),
                ("backend", Json::Str(backend.name().to_string())),
                ("loop_speedup", Json::Num(ls)),
                ("block_speedup", Json::Num(bs)),
                (
                    "blocks",
                    Json::Arr(
                        sol.blocks
                            .iter()
                            .map(|b| {
                                Json::obj(vec![
                                    (
                                        "function",
                                        Json::Str(b.func.clone()),
                                    ),
                                    (
                                        "kind",
                                        Json::Str(
                                            b.kind.name().to_string(),
                                        ),
                                    ),
                                    (
                                        "core_speedup",
                                        Json::Num(b.speedup()),
                                    ),
                                    (
                                        "confirmed",
                                        Json::Bool(b.confirmed),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]));
        }
    }

    table.print();

    // The acceptance bar: the function-block path must strictly beat
    // loop-only for at least one bundled app on the paper's FPGA
    // destination under the same seed.
    assert!(
        strictly_better_anywhere,
        "no bundled app improved with function blocks enabled"
    );

    save_results(
        "BENCH_funcblock",
        &Json::obj(vec![("results", Json::Arr(rows_json))]),
    );
    println!("\nseries recorded: target/bench-results/BENCH_funcblock.json");
    println!("function-block acceptance shape: PASS");
}
