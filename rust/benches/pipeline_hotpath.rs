//! §Perf bench: the coordinator's own hot path (no modeled compiles —
//! the real wall-clock cost of parse → typecheck → profile → funnel →
//! simulate on this machine).
//!
//! The profiling run (the instrumented interpreter over ~10^5..10^6 loop
//! iterations) dominates; everything else must be sub-millisecond. This
//! is the bench the §Perf optimization pass iterates against.

use fpga_offload::analysis::analyze;
use fpga_offload::codegen::split;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::fpga::simulate;
use fpga_offload::hls::{estimate, precompile, ARRIA10_GX};
use fpga_offload::minic::{
    parse, resolve, typecheck, Interp, ResolveOpts, Vm,
};
use fpga_offload::search::{funnel, search, SearchConfig};
use fpga_offload::util::bench::{bench, save_results};
use fpga_offload::util::json::Json;
use fpga_offload::workloads;

fn main() {
    println!("== coordinator hot path (real wall-clock) ==\n");
    let src = workloads::TDFIR_C;
    let cfg = SearchConfig::default();

    let s_parse = bench("hotpath/parse(tdfir.c)", 3, 50, || {
        let _ = parse(src).unwrap();
    });
    let prog = parse(src).unwrap();

    let s_check = bench("hotpath/typecheck", 3, 50, || {
        assert!(typecheck::check(&prog).is_empty());
    });

    let s_profile = bench("hotpath/profile(interpreter)", 1, 5, || {
        let mut i = Interp::new(&prog).unwrap();
        i.call("main", &[]).unwrap();
    });

    // The same profiling run on the slot-resolved bytecode VM — the
    // default engine. Includes per-run lowering, like Interp::new's
    // per-run setup, so the comparison is end to end.
    let s_profile_vm = bench("hotpath/profile(vm)", 1, 5, || {
        let mut v = Vm::new(&prog).unwrap();
        v.call("main", &[]).unwrap();
    });
    let s_compile = bench("hotpath/vm-lowering(only)", 3, 50, || {
        let _ = resolve::compile(&prog).unwrap();
    });
    let vm_speedup = s_profile.mean_ms() / s_profile_vm.mean_ms();
    println!("  -> vm speedup over tree-walker: {vm_speedup:.1}x");

    // §PGO series: the fused-superinstruction encoding vs the unfused
    // baseline, per bundled workload. Both run from precompiled modules
    // so the comparison isolates dispatch cost — the thing the PGO pass
    // (arm reorder + superinstructions) actually moves.
    let mut pgo_rows: Vec<(&str, Json)> = Vec::new();
    let mut best_speedup = 0.0f64;
    for app in workloads::APPS {
        let app_prog = parse(workloads::source(app).unwrap()).unwrap();
        let base_m =
            resolve::compile_with(&app_prog, &ResolveOpts::baseline())
                .unwrap();
        let pgo_m = resolve::compile(&app_prog).unwrap();
        let s_base =
            bench(&format!("hotpath/vm-baseline({app})"), 1, 5, || {
                let mut v = Vm::from_module(base_m.clone()).unwrap();
                v.call("main", &[]).unwrap();
            });
        let s_pgo = bench(&format!("hotpath/vm-pgo({app})"), 1, 5, || {
            let mut v = Vm::from_module(pgo_m.clone()).unwrap();
            v.call("main", &[]).unwrap();
        });
        let x = s_base.mean_ms() / s_pgo.mean_ms();
        best_speedup = best_speedup.max(x);
        println!("  -> {app}: pgo encoding {x:.2}x over unfused baseline");
        pgo_rows.push((
            app,
            Json::obj(vec![
                ("vm_ms", Json::Num(s_base.mean_ms())),
                ("vm_pgo_ms", Json::Num(s_pgo.mean_ms())),
                ("speedup", Json::Num(x)),
            ]),
        ));
    }

    let an = analyze(&prog, "main").unwrap();
    let s_funnel = bench("hotpath/funnel(narrow+precompile)", 3, 50, || {
        let _ = funnel::run(&prog, &an, &cfg, &ARRIA10_GX).unwrap();
    });

    // First rank-ordered candidate that the splitter accepts (top-ranked
    // loops can be rejected, e.g. scalar write-back shapes).
    let (al, sp) = an
        .ranked_candidates()
        .into_iter()
        .find_map(|al| split(&prog, al).ok().map(|sp| (al, sp)))
        .expect("a splittable candidate");
    let s_estimate = bench("hotpath/estimate(one kernel)", 10, 200, || {
        let _ = estimate(&sp.kernel);
    });
    let s_report = bench("hotpath/precompile-report", 10, 200, || {
        let _ = precompile(
            &sp.kernel,
            al.intensity.as_ref().unwrap(),
            &ARRIA10_GX,
        );
    });
    let s_sim = bench("hotpath/simulate(one pattern)", 10, 200, || {
        let _ =
            simulate(&an, &[sp.kernel.clone()], &XEON_BRONZE_3104, &ARRIA10_GX)
                .unwrap();
    });
    let s_search = bench("hotpath/full-search(no profiling)", 1, 5, || {
        let _ = search(
            "tdfir",
            &prog,
            &an,
            &cfg,
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        )
        .unwrap();
    });

    // §Perf targets (DESIGN.md §6): static stages in single-digit ms;
    // the profiling run is the only stage allowed above that, and the
    // VM engine must beat the tree-walker by ≥5x on it.
    assert!(s_parse.mean_ms() < 10.0, "parse too slow");
    assert!(s_check.mean_ms() < 10.0, "typecheck too slow");
    assert!(s_funnel.mean_ms() < 10.0, "funnel too slow");
    assert!(s_estimate.mean_ms() < 1.0, "estimate too slow");
    assert!(s_sim.mean_ms() < 1.0, "simulate too slow");
    assert!(s_compile.mean_ms() < 10.0, "vm lowering too slow");
    assert!(
        vm_speedup >= 5.0,
        "vm must be ≥5x the tree-walker on the profiling run, got {vm_speedup:.1}x"
    );
    assert!(
        best_speedup >= 1.3,
        "pgo encoding must be ≥1.3x the unfused baseline on at least \
         one workload, got best {best_speedup:.2}x"
    );
    println!(
        "\nperf targets: PASS (static pipeline in single-digit ms, \
         vm ≥5x, pgo ≥1.3x)"
    );

    // Both engine series recorded so the perf trajectory has history:
    // target/bench-results/BENCH_hotpath.json.
    save_results(
        "BENCH_hotpath",
        &Json::obj(vec![
            ("parse_ms", Json::Num(s_parse.mean_ms())),
            ("typecheck_ms", Json::Num(s_check.mean_ms())),
            ("profile_interp_ms", Json::Num(s_profile.mean_ms())),
            ("profile_vm_ms", Json::Num(s_profile_vm.mean_ms())),
            ("vm_lowering_ms", Json::Num(s_compile.mean_ms())),
            ("vm_speedup", Json::Num(vm_speedup)),
            ("funnel_ms", Json::Num(s_funnel.mean_ms())),
            ("estimate_ms", Json::Num(s_estimate.mean_ms())),
            ("report_ms", Json::Num(s_report.mean_ms())),
            ("simulate_ms", Json::Num(s_sim.mean_ms())),
            ("search_ms", Json::Num(s_search.mean_ms())),
            ("vm-pgo", Json::obj(pgo_rows)),
            ("vm_pgo_best_speedup", Json::Num(best_speedup)),
        ]),
    );
}
