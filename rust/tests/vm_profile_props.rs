//! Property tests for the §PGO opcode profiler.
//!
//! Three invariants lock the profiler down as a measurement tool:
//!
//!   1. Conservation: the per-opcode counters sum to exactly the number
//!      of dispatched instructions, and adjacent-pair counts sum to
//!      dispatches - 1 (every dispatch after the first closes a pair).
//!   2. Determinism: the rendered report and its JSON form are
//!      byte-identical across repeated runs and across thread
//!      schedules — no wall-clock, no iteration-order leaks.
//!   3. Invisibility: enabling the profiler changes nothing observable
//!      (result value, loop profile, dispatch count), and a plain run
//!      matches the tree-walking oracle.

use fpga_offload::minic::{
    parse, Interp, Op, ResolveOpts, Value, Vm,
};
use fpga_offload::workloads;

/// A small fusion-rich program: counted loops (CmpConstJump,
/// CompoundLocalConst), indexed loads/stores (LoadIndexLocal,
/// StoreIndexLocal), computed indices feeding multiplies
/// (LoadIndexBin), and a local MAC (MacLocal).
const SRC: &str = "\
float t[40];
float acc;
int main() {
    for (int i = 0; i < 40; i++) {
        t[i] = i * 0.25 - 3.0;
    }
    float lacc = 0.0;
    for (int r = 0; r < 50; r++) {
        for (int c = 1; c < 40; c++) {
            lacc += t[c] * 0.5;
            acc = acc + 2.0 * t[c - 1];
            t[c] += 0.125;
        }
    }
    acc += lacc;
    return (int) acc;
}
";

fn run_profiled(opts: &ResolveOpts) -> (Value, Vm, String, String) {
    let prog = parse(SRC).unwrap();
    let mut vm = Vm::new_profiled_with(&prog, opts).unwrap();
    let v = vm.call("main", &[]).unwrap();
    let report = vm
        .instr_profiler()
        .expect("profiled VM exposes its profiler")
        .report(10);
    let text = report.render();
    let json = report.to_json().pretty();
    (v, vm, text, json)
}

#[test]
fn counters_conserve_dispatches() {
    for opts in [
        ResolveOpts::default(),
        ResolveOpts::baseline(),
        ResolveOpts::regs(),
    ] {
        let (_, vm, _, _) = run_profiled(&opts);
        let p = vm.instr_profiler().unwrap();
        let total: u64 = Op::ALL.iter().map(|&op| p.count(op)).sum();
        assert_eq!(
            total,
            p.dispatches(),
            "{opts:?}: opcode counts must sum to dispatches"
        );
        assert_eq!(
            vm.dispatches(),
            p.dispatches(),
            "{opts:?}: VM step count and profiler disagree"
        );
        assert_eq!(
            p.pair_total(),
            p.dispatches() - 1,
            "{opts:?}: every dispatch after the first closes one pair"
        );
    }
}

#[test]
fn counters_conserve_on_a_bundled_workload() {
    let prog = parse(workloads::source("mriq").unwrap()).unwrap();
    let mut vm = Vm::new_profiled(&prog).unwrap();
    vm.call("main", &[]).unwrap();
    let p = vm.instr_profiler().unwrap();
    let total: u64 = Op::ALL.iter().map(|&op| p.count(op)).sum();
    assert_eq!(total, p.dispatches());
    assert_eq!(p.pair_total(), p.dispatches() - 1);
    assert!(p.dispatches() > 10_000, "mriq should dispatch plenty");
}

#[test]
fn reports_are_byte_identical_across_runs() {
    let (v1, _, text1, json1) = run_profiled(&ResolveOpts::default());
    let (v2, _, text2, json2) = run_profiled(&ResolveOpts::default());
    assert_eq!(v1, v2);
    assert_eq!(text1, text2, "rendered report must be deterministic");
    assert_eq!(json1, json2, "JSON report must be deterministic");
}

#[test]
fn reports_are_byte_identical_across_thread_schedules() {
    let (_, _, text0, json0) = run_profiled(&ResolveOpts::default());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(|| {
                let (_, _, text, json) =
                    run_profiled(&ResolveOpts::default());
                (text, json)
            })
        })
        .collect();
    for h in handles {
        let (text, json) = h.join().unwrap();
        assert_eq!(text, text0, "report differs across threads");
        assert_eq!(json, json0, "JSON differs across threads");
    }
}

#[test]
fn profiling_is_observably_invisible() {
    let prog = parse(SRC).unwrap();

    let mut plain = Vm::new(&prog).unwrap();
    let v_plain = plain.call("main", &[]).unwrap();
    let (v_prof, prof_vm, _, _) = run_profiled(&ResolveOpts::default());

    assert_eq!(v_plain, v_prof, "profiler changed the result");
    assert_eq!(
        plain.dispatches(),
        prof_vm.dispatches(),
        "profiler changed the dispatch count"
    );
    assert!(plain.instr_profiler().is_none(), "plain VM carries no profiler");

    let pp = plain.profile();
    let qp = prof_vm.profile();
    assert_eq!(pp.total, qp.total, "profiler perturbed the op counts");
    assert_eq!(pp.loops.len(), qp.loops.len());
    for (id, lp) in &pp.loops {
        let lq = qp.loop_profile(*id).unwrap();
        assert_eq!(lp.entries, lq.entries);
        assert_eq!(lp.trips, lq.trips);
        assert_eq!(lp.ops, lq.ops);
    }

    // And the whole stack agrees with the tree-walking oracle.
    let mut oracle = Interp::new(&prog).unwrap();
    let v_oracle = oracle.call("main", &[]).unwrap();
    assert_eq!(v_oracle, v_plain);
    assert_eq!(oracle.profile().total, pp.total);
}
