//! End-to-end tests of the service tier (ISSUE 7 satellite 4): hit/miss
//! service classes under concurrent clients, typed admission control,
//! deadline handling, in-flight coalescing, graceful shutdown, and the
//! TCP line protocol.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use fpga_offload::analysis::Analysis;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::PatternDb;
use fpga_offload::hls::{Device, ARRIA10_GX};
use fpga_offload::minic::Program;
use fpga_offload::runtime::{Artifacts, Runtime, SampleRun};
use fpga_offload::search::backend::BackendMeasurement;
use fpga_offload::search::funnel::Candidate;
use fpga_offload::search::measure::SearchError;
use fpga_offload::search::patterns::Pattern;
use fpga_offload::search::{Backend, FpgaBackend, SearchConfig};
use fpga_offload::service::{
    BackendKind, Client, PlanRequest, Service, ServiceConfig, TcpServer,
};
use fpga_offload::util::json::Json;
use fpga_offload::util::tempdir::TempDir;

/// Fast two-loop source every test can solve in milliseconds.
const GOOD: &str = "
#define N 1024
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

/// `GOOD` with `n + 1` trailing newlines: same program, distinct source
/// fingerprint. The `ReuseKey` is app-name-blind (it keys on
/// source/entry/backend/config), so tests that need distinct cold
/// solves — rather than coalescing onto one in-flight key — must vary
/// the source text itself.
fn uniq(n: usize) -> String {
    format!("{GOOD}{}", "\n".repeat(n + 1))
}

fn cfg_with_db(dir: &TempDir) -> ServiceConfig {
    ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 2,
        ..ServiceConfig::default()
    }
}

/// Unix time, seconds — the same clock the pattern DB stamps with.
fn now_secs() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_secs()
}

#[test]
fn concurrent_clients_mixed_hits_and_misses() {
    let dir = TempDir::new("svc-e2e-mixed").unwrap();
    let svc = Arc::new(Service::start(cfg_with_db(&dir)).unwrap());
    // Warm one app so the flood below mixes hits with cold solves.
    let warmup = svc.request(PlanRequest::new("hot", GOOD));
    assert!(warmup.ok(), "warmup failed: {:?}", warmup.result);

    let handles: Vec<_> = (0..8)
        .map(|i| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                if i % 2 == 0 {
                    svc.request(PlanRequest::new("hot", GOOD))
                } else {
                    // Distinct sources → distinct reuse keys → real
                    // cold solves (identical sources would coalesce).
                    svc.request(PlanRequest::new(
                        format!("cold{i}"),
                        uniq(i),
                    ))
                }
            })
        })
        .collect();
    let responses: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for resp in &responses {
        assert!(resp.ok(), "{}: {:?}", resp.app, resp.result);
    }
    let hits = responses.iter().filter(|r| r.is_hit()).count();
    assert_eq!(hits, 4, "every 'hot' request should hit the index");
    let snap = svc.stats();
    assert_eq!(snap.hits, 4);
    // warmup + 4 cold apps solved.
    assert_eq!(snap.misses, 5);
    assert_eq!(snap.rejected, 0);
    svc.shutdown();
    // Records persisted: a fresh service over the same dir hits warm.
    let svc2 = Service::start(cfg_with_db(&dir)).unwrap();
    let warm = svc2.request(PlanRequest::new("cold1", uniq(1)));
    assert!(warm.is_hit(), "restart lost the index: {:?}", warm.result);
}

#[test]
fn queue_full_is_a_typed_reject_with_retry_hint() {
    // No workers: admitted jobs stay queued, so capacity is exact.
    let cfg = ServiceConfig {
        workers: 0,
        queue_cap: 2,
        backend: BackendKind::Cpu,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(Service::start(cfg).unwrap());
    for i in 0..2 {
        // Distinct sources: identical ones would coalesce onto the
        // first in-flight key instead of taking queue slots.
        let mut req = PlanRequest::new(format!("fill{i}"), uniq(i));
        req.deadline_ms = Some(0); // return immediately, job stays queued
        let resp = svc.request(req);
        assert!(resp.is_timeout(), "fill{i}: {:?}", resp.result);
    }
    assert_eq!(svc.stats().queue_depth, 2);
    let mut req = PlanRequest::new("overflow", uniq(2));
    req.deadline_ms = Some(0);
    let resp = svc.request(req);
    assert!(resp.is_rejected(), "expected reject: {:?}", resp.result);
    assert!(!resp.is_timeout());
    let err = resp.result.unwrap_err();
    assert_eq!(err.stage.as_str(), "queue");
    assert_eq!(err.class.as_str(), "transient");
    assert!(resp.retry_after_ms.unwrap() >= 1);
    assert_eq!(svc.stats().rejected, 1);
    svc.shutdown();
}

#[test]
fn expired_deadline_returns_typed_timeout_not_a_hang() {
    let cfg = ServiceConfig {
        workers: 1,
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg).unwrap();
    let mut req = PlanRequest::new("expired", GOOD);
    req.deadline_ms = Some(0);
    let resp = svc.request(req);
    assert!(resp.is_timeout(), "expected timeout: {:?}", resp.result);
    let err = resp.result.unwrap_err();
    assert_eq!(err.stage.as_str(), "queue");
    assert_eq!(err.class.as_str(), "timeout");
    assert_eq!(svc.stats().timeouts, 1);
    // The pool is still healthy: an unbounded request is served. A
    // distinct source keeps it off the expired job's reuse key, so it
    // cannot coalesce onto a broadcast that races the worker's skip.
    let ok = svc.request(PlanRequest::new("healthy", uniq(1)));
    assert!(ok.ok(), "{:?}", ok.result);
    svc.shutdown();
}

/// Delegates to the real FPGA backend but blocks every `measure` until
/// the gate opens — makes "in flight" a controllable state.
struct GateBackend {
    inner: FpgaBackend<'static>,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl GateBackend {
    fn new() -> (Self, Arc<(Mutex<bool>, Condvar)>) {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let backend = GateBackend {
            inner: FpgaBackend {
                cpu: &XEON_BRONZE_3104,
                device: &ARRIA10_GX,
            },
            gate: Arc::clone(&gate),
        };
        (backend, gate)
    }
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (lock, cv) = &**gate;
    *lock.lock().unwrap() = true;
    cv.notify_all();
}

impl Backend for GateBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn device(&self) -> &Device {
        self.inner.device()
    }

    fn measure(
        &self,
        prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        drop(open);
        self.inner.measure(prog, analysis, cands, pattern, cfg)
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        self.inner.verify(prog, cands, pattern, entry, cfg)
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        self.inner.deploy_check(sample, env, seed)
    }
}

#[test]
fn duplicate_in_flight_requests_coalesce_into_one_solve() {
    let dir = TempDir::new("svc-e2e-coalesce").unwrap();
    let (backend, gate) = GateBackend::new();
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        ..ServiceConfig::default()
    };
    let svc =
        Arc::new(Service::with_backend(cfg, Box::new(backend)).unwrap());

    const K: usize = 4;
    let handles: Vec<_> = (0..K)
        .map(|_| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                svc.request(PlanRequest::new("dup", GOOD))
            })
        })
        .collect();
    // All K requests target one key; wait until K-1 have coalesced onto
    // the single in-flight solve (the worker is parked at the gate).
    let mut spins = 0;
    while svc.stats().coalesced < (K - 1) as u64 {
        std::thread::sleep(Duration::from_millis(5));
        spins += 1;
        assert!(spins < 2000, "coalescing never converged");
    }
    assert_eq!(svc.stats().inflight, 1, "one key in flight");
    open_gate(&gate);
    let responses: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let mut patterns = Vec::new();
    for resp in responses {
        assert!(resp.ok(), "{:?}", resp.result);
        patterns.push(resp.result.unwrap().best_pattern);
    }
    patterns.dedup();
    assert_eq!(patterns.len(), 1, "every waiter got the identical plan");
    let snap = svc.stats();
    assert_eq!(snap.solves, 1, "exactly one funnel run for K requests");
    assert_eq!(snap.coalesced, (K - 1) as u64);
    svc.shutdown();
}

#[test]
fn graceful_shutdown_drains_admitted_work_then_rejects() {
    let dir = TempDir::new("svc-e2e-drain").unwrap();
    let (backend, gate) = GateBackend::new();
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        queue_cap: 8,
        ..ServiceConfig::default()
    };
    let svc =
        Arc::new(Service::with_backend(cfg, Box::new(backend)).unwrap());
    // Two distinct jobs: one the worker picks up (parked at the gate),
    // one waiting in the queue.
    let t1 = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            svc.request(PlanRequest::new("drain_a", uniq(1)))
        })
    };
    let t2 = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            svc.request(PlanRequest::new("drain_b", uniq(2)))
        })
    };
    let mut spins = 0;
    while svc.stats().inflight < 2 {
        std::thread::sleep(Duration::from_millis(5));
        spins += 1;
        assert!(spins < 2000, "jobs never got admitted");
    }
    // Drain on a separate thread (shutdown blocks until workers finish),
    // then release the gate so the drain can complete.
    let drainer = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || svc.shutdown())
    };
    // Once the drain's close lands, new work gets a typed reject. An
    // attempt racing ahead of the close is admitted but carries an
    // expired deadline, so the worker skips it without solving.
    let mut saw_reject = false;
    for i in 0..200 {
        let mut late = PlanRequest::new(format!("late{i}"), uniq(10 + i));
        late.deadline_ms = Some(0);
        let resp = svc.request(late);
        if resp.is_rejected() {
            saw_reject = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(saw_reject, "draining service never rejected new work");
    open_gate(&gate);
    drainer.join().unwrap();
    // Both admitted requests were served, not dropped.
    let ra = t1.join().unwrap();
    let rb = t2.join().unwrap();
    assert!(ra.ok(), "drain_a dropped: {:?}", ra.result);
    assert!(rb.ok(), "drain_b dropped: {:?}", rb.result);
    assert_eq!(svc.stats().solves, 2);
}

#[test]
fn refresh_ahead_serves_stale_hit_and_schedules_research() {
    let dir = TempDir::new("svc-e2e-refresh").unwrap();
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        max_age: Some(Duration::from_secs(1000)),
        refresh_ahead: 0.8,
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg).unwrap();
    let cold = svc.request(PlanRequest::new("aging", GOOD));
    assert!(cold.ok(), "{:?}", cold.result);

    // Age the stored record to 90% of max_age: inside the serve window,
    // past the refresh threshold.
    let db = PatternDb::open(dir.path()).unwrap();
    let aged = now_secs() - 900;
    assert!(db.restamp("aging", aged).unwrap());

    // A fresh service (index loaded from disk) must serve the hit AND
    // schedule the background re-search.
    let cfg2 = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        max_age: Some(Duration::from_secs(1000)),
        refresh_ahead: 0.8,
        ..ServiceConfig::default()
    };
    let svc2 = Service::start(cfg2).unwrap();
    let warm = svc2.request(PlanRequest::new("aging", GOOD));
    assert!(warm.is_hit(), "aged-but-valid must hit: {:?}", warm.result);
    let plan = warm.result.unwrap();
    assert!(plan.refresh_ahead, "refresh window not flagged");
    let mut spins = 0;
    while svc2.stats().refreshes_done < 1 {
        std::thread::sleep(Duration::from_millis(5));
        spins += 1;
        assert!(spins < 2000, "background refresh never completed");
    }
    svc2.shutdown();
    // The re-search rewrote the record with a fresh stamp.
    let rec = db.load_record("aging").unwrap().unwrap();
    assert!(
        rec.stored_at.unwrap() > aged,
        "record stamp was not refreshed: {:?} <= {aged}",
        rec.stored_at
    );
    // And a record *past* max_age is a miss, not a hit.
    let old = now_secs() - 2000;
    assert!(db.restamp("aging", old).unwrap());
    let cfg3 = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        max_age: Some(Duration::from_secs(1000)),
        ..ServiceConfig::default()
    };
    let svc3 = Service::start(cfg3).unwrap();
    let expired = svc3.request(PlanRequest::new("aging", GOOD));
    assert!(expired.ok());
    assert!(
        !expired.is_hit(),
        "expired record must re-search, got a hit"
    );
    svc3.shutdown();
}

#[test]
fn tcp_round_trip_plan_stats_ping_and_malformed_lines() {
    let dir = TempDir::new("svc-e2e-tcp").unwrap();
    let server =
        TcpServer::bind(Service::start(cfg_with_db(&dir)).unwrap(), "127.0.0.1:0")
            .unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();

    let pong = client.ping(1).unwrap();
    assert_eq!(pong.get(&["status"]).and_then(Json::as_str), Some("ok"));
    assert_eq!(pong.get(&["id"]).and_then(Json::as_f64), Some(1.0));

    // Bundled app by name only — the server resolves source and entry.
    let plan = client.plan(2, "sobel", None, None).unwrap();
    assert_eq!(
        plan.get(&["status"]).and_then(Json::as_str),
        Some("ok"),
        "plan failed: {plan}"
    );
    assert_eq!(plan.get(&["class"]).and_then(Json::as_str), Some("miss"));
    let again = client.plan(3, "sobel", None, None).unwrap();
    assert_eq!(
        again.get(&["class"]).and_then(Json::as_str),
        Some("hit"),
        "second identical request must hit: {again}"
    );
    assert_eq!(
        again.get(&["cached"]).and_then(Json::as_bool),
        Some(true)
    );

    // Inline source round-trips through JSON string escaping.
    let inline = client.plan(4, "inline", Some(GOOD), None).unwrap();
    assert_eq!(
        inline.get(&["status"]).and_then(Json::as_str),
        Some("ok"),
        "inline plan failed: {inline}"
    );

    // Malformed line → error response, connection survives.
    {
        use std::io::{BufRead, BufReader, Write};
        let raw = std::net::TcpStream::connect(&addr).unwrap();
        let mut w = raw.try_clone().unwrap();
        writeln!(w, "{{this is not json").unwrap();
        let mut line = String::new();
        BufReader::new(raw).read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(
            resp.get(&["status"]).and_then(Json::as_str),
            Some("error"),
            "malformed line: {resp}"
        );
    }
    // A parseable line that isn't a valid request also errors politely.
    let bad = client.roundtrip(&Json::Str("not an object".into()));
    let bad = bad.unwrap();
    assert_eq!(bad.get(&["status"]).and_then(Json::as_str), Some("error"));
    let unknown_app =
        client.roundtrip(&Json::obj(vec![("app", Json::Str("ghost".into()))]));
    let unknown_app = unknown_app.unwrap();
    assert_eq!(
        unknown_app.get(&["status"]).and_then(Json::as_str),
        Some("error")
    );
    let still_alive = client.ping(5).unwrap();
    assert_eq!(
        still_alive.get(&["status"]).and_then(Json::as_str),
        Some("ok")
    );

    let stats = client.stats(6).unwrap();
    let hits = stats.get(&["stats", "hits"]).and_then(Json::as_f64);
    assert_eq!(hits, Some(1.0), "stats endpoint: {stats}");
    assert!(
        stats
            .get(&["stats", "hit_p50_us"])
            .and_then(Json::as_f64)
            .is_some(),
        "latency quantiles missing: {stats}"
    );
    // The sharded store's counters ride the same flat stats object —
    // the contract `repro client --stats` dashboards and the CI smoke
    // assert on.
    for key in [
        "evictions",
        "compactions",
        "stale_hits",
        "appends",
        "store_hits",
        "store_misses",
        "torn_truncations",
    ] {
        assert!(
            stats.get(&["stats", key]).and_then(Json::as_f64).is_some(),
            "store counter {key} missing from stats: {stats}"
        );
    }

    let ack = client.shutdown(7).unwrap();
    assert_eq!(ack.get(&["status"]).and_then(Json::as_str), Some("ok"));
    server.wait();
}
