//! End-to-end tests of the observability layer (ISSUE 9 tentpole):
//! one trace id links TCP-facing admission to the final shard append,
//! seeded fault runs replay byte-identical span trees on the virtual
//! clock, and a saturated span ring degrades by dropping spans — never
//! by blocking or poisoning the request path.

use std::sync::Arc;

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::obs::{SpanRecord, TraceConfig, Tracer};
use fpga_offload::search::{
    FaultPlan, FaultyBackend, FpgaBackend, RetryPolicy, SimClock,
};
use fpga_offload::service::{PlanRequest, Service, ServiceConfig};
use fpga_offload::util::tempdir::TempDir;

/// Fast two-loop source every test can solve in milliseconds.
const GOOD: &str = "
#define N 1024
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

/// A `'static` inner backend so [`FaultyBackend`] (which borrows its
/// inner) can be boxed into the service.
static FPGA: FpgaBackend<'static> = FpgaBackend {
    cpu: &XEON_BRONZE_3104,
    device: &ARRIA10_GX,
};

#[test]
fn one_trace_id_links_admission_to_shard_append() {
    let dir = TempDir::new("obs-e2e-one-trace").unwrap();
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg).unwrap();
    let resp = svc.request(PlanRequest::new("traced", GOOD));
    assert!(resp.ok(), "{:?}", resp.result);
    svc.shutdown();

    let spans = svc.spans();
    let roots: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.parent_id == 0).collect();
    assert_eq!(roots.len(), 1, "one request mints one root: {roots:?}");
    let root = roots[0];
    assert_eq!(root.name, "request");
    assert_eq!(root.detail, "traced");

    // Every span the request produced — on the caller thread, the
    // worker thread, and the batch's scoped destination threads —
    // carries the root's trace id.
    for s in &spans {
        assert_eq!(
            s.trace_id, root.trace_id,
            "span {} escaped the trace",
            s.name
        );
    }
    // The full journey is present: admission (with its index probe),
    // queue wait, the worker's solve, the batch destination, each
    // pipeline stage, and the final pattern-store append.
    for name in [
        "admission",
        "store.read",
        "queue.wait",
        "solve",
        "destination",
        "stage.parse",
        "stage.analyze",
        "stage.measure",
        "stage.select",
        "store.append",
    ] {
        assert!(
            spans.iter().any(|s| s.name == name),
            "trace is missing a {name} span: {:?}",
            spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
    }
    // Parent links resolve within the trace: every non-root span's
    // parent is a recorded span or the root itself.
    for s in spans.iter().filter(|s| s.parent_id != 0) {
        assert!(
            spans.iter().any(|p| p.span_id == s.parent_id),
            "span {} has a dangling parent {}",
            s.name,
            s.parent_id
        );
    }
}

/// A service whose backend, retry clock, and tracer all share one
/// virtual clock — the determinism seam under seeded fault injection.
fn faulty_service(seed: u64, dir: &TempDir) -> Service {
    let clock = SimClock::new();
    let backend = FaultyBackend::new(
        &FPGA,
        FaultPlan::from_seed(seed),
        clock.clone(),
    );
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        retry: Some(RetryPolicy {
            max_attempts: 4,
            stage_deadline_s: Some(30.0),
            seed,
            ..RetryPolicy::default()
        }),
        ..ServiceConfig::default()
    };
    Service::with_backend_on_clock(cfg, Box::new(backend), clock).unwrap()
}

#[test]
fn seeded_fault_runs_replay_identical_span_trees() {
    let run = |label: &str| -> Vec<SpanRecord> {
        let dir = TempDir::new(label).unwrap();
        let svc = faulty_service(7, &dir);
        // The seeded plan decides whether the solve survives its
        // faults; both runs must agree on the outcome either way.
        let _ = svc.request(PlanRequest::new("det", GOOD));
        svc.shutdown();
        svc.spans()
    };
    let a = run("obs-e2e-det-a");
    let b = run("obs-e2e-det-b");
    assert!(!a.is_empty(), "traced run recorded nothing");
    assert_eq!(a, b, "same seed must replay the same span tree");
    // The replayed tree really exercised the retry layer: wrapped
    // backend calls and per-attempt spans are present.
    let names: Vec<&str> = a.iter().map(|s| s.name).collect();
    assert!(names.contains(&"backend.measure"), "{names:?}");
    assert!(names.contains(&"retry.attempt"), "{names:?}");
}

#[test]
fn saturated_span_ring_drops_spans_but_serves_every_request() {
    let dir = TempDir::new("obs-e2e-saturate").unwrap();
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 2,
        trace: TraceConfig {
            capacity: 4,
            ..TraceConfig::default()
        },
        ..ServiceConfig::default()
    };
    let svc = Arc::new(Service::start(cfg).unwrap());
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                // Distinct sources → distinct reuse keys → real
                // concurrent solves, each minting a span flood far
                // beyond the 4-slot ring.
                let src = format!("{GOOD}{}", "\n".repeat(i + 1));
                svc.request(PlanRequest::new(format!("sat{i}"), src))
            })
        })
        .collect();
    for h in handles {
        let resp = h.join().unwrap();
        assert!(resp.ok(), "{}: {:?}", resp.app, resp.result);
    }
    assert!(svc.spans().len() <= 4, "ring exceeded its capacity");
    assert!(
        svc.tracer().dropped() > 0,
        "this workload was sized to overflow the ring"
    );
    svc.shutdown();
}

#[test]
fn dropping_a_tracer_handle_mid_flight_never_blocks_recording() {
    let tracer = Tracer::new(&TraceConfig::default());
    let barrier = Arc::new(std::sync::Barrier::new(2));
    let worker = {
        let tracer = tracer.clone();
        let barrier = Arc::clone(&barrier);
        std::thread::spawn(move || {
            let _root = tracer.trace("request", "doomed");
            let _stage = fpga_offload::obs::span("stage.parse");
            barrier.wait(); // main drops its handle now
            barrier.wait(); // handle gone; keep recording
            {
                let _late = fpga_offload::obs::span("stage.measure");
            }
            tracer.spans().len()
        })
    };
    barrier.wait();
    drop(tracer); // the worker's clone keeps the collector alive
    barrier.wait();
    let recorded = worker.join().unwrap();
    assert!(recorded >= 1, "late span was lost: {recorded}");
}
