//! §5.1.2 conditions: loop inventories and experiment-condition checks
//! against the paper's stated numbers.

use fpga_offload::analysis::{analyze, loopinfo};
use fpga_offload::minic::parse;
use fpga_offload::search::SearchConfig;
use fpga_offload::workloads;

#[test]
fn tdfir_has_36_loops() {
    let prog = parse(workloads::TDFIR_C).unwrap();
    assert_eq!(prog.loop_count, 36);
    assert_eq!(loopinfo::extract(&prog).len(), 36);
}

#[test]
fn mriq_has_16_loops() {
    let prog = parse(workloads::MRIQ_C).unwrap();
    assert_eq!(prog.loop_count, 16);
    assert_eq!(loopinfo::extract(&prog).len(), 16);
}

#[test]
fn paper_config_is_default() {
    let cfg = SearchConfig::default();
    assert_eq!(
        (cfg.top_a, cfg.unroll, cfg.top_c, cfg.max_patterns),
        (5, 1, 3, 4),
        "§5.1.2: A=5, B=1, C=3, D=4"
    );
}

#[test]
fn loop_ids_are_dense_and_source_ordered() {
    for app in workloads::APPS {
        let prog = parse(workloads::source(app).unwrap()).unwrap();
        let info = loopinfo::extract(&prog);
        for (i, l) in info.iter().enumerate() {
            assert_eq!(l.id.0 as usize, i, "{app}: non-dense loop ids");
        }
        // Source order: line numbers non-decreasing within a function
        // chain is too strict across functions; check per function.
        for f in info
            .iter()
            .map(|l| l.function.clone())
            .collect::<std::collections::BTreeSet<_>>()
        {
            let lines: Vec<u32> = info
                .iter()
                .filter(|l| l.function == f)
                .map(|l| l.line)
                .collect();
            let mut sorted = lines.clone();
            sorted.sort_unstable();
            assert_eq!(lines, sorted, "{app}/{f}: loop order");
        }
    }
}

#[test]
fn every_loop_in_bundled_apps_executes() {
    // The paper counts loop statements the profiler can observe; our
    // workloads are written so no loop is dead code.
    for app in workloads::APPS {
        let prog = parse(workloads::source(app).unwrap()).unwrap();
        let an = analyze(&prog, "main").unwrap();
        assert!(
            an.cold_loops().is_empty(),
            "{app}: dead loops {:?}",
            an.cold_loops()
        );
    }
}

#[test]
fn hot_loops_rank_first() {
    // tdfir: the bank nest (L12..L15) must occupy the top intensity ranks;
    // mriq: the Q nest (L4/L5).
    let prog = parse(workloads::TDFIR_C).unwrap();
    let an = analyze(&prog, "main").unwrap();
    let top: Vec<u32> = an
        .ranked_candidates()
        .iter()
        .take(4)
        .map(|l| l.id().0)
        .collect();
    assert!(
        top.iter().filter(|id| (12..=15).contains(*id)).count() >= 3,
        "tdfir top-4 {top:?} should be dominated by the bank nest"
    );

    let prog = parse(workloads::MRIQ_C).unwrap();
    let an = analyze(&prog, "main").unwrap();
    let top: Vec<u32> = an
        .ranked_candidates()
        .iter()
        .take(2)
        .map(|l| l.id().0)
        .collect();
    assert!(
        top.contains(&4) || top.contains(&5),
        "mriq top-2 {top:?} should contain the Q nest"
    );
}
