//! End-to-end function-block offloading: the staged pipeline with
//! `func_blocks` on, against the bundled workloads.
//!
//! The acceptance bar (ISSUE 4): with blocks enabled at least one
//! bundled workload achieves a *strictly* higher verified speedup than
//! its loop-only result under the same seed; every accepted replacement
//! is behaviorally confirmed; structurally-similar-but-semantically-
//! different functions are never replaced.

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{Batch, OffloadRequest, Pipeline, TestDb};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    CpuBaseline, FpgaBackend, GpuBackend, SearchConfig,
};
use fpga_offload::workloads;

fn fpga_backend() -> FpgaBackend<'static> {
    FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

fn request(app: &str, func_blocks: bool) -> OffloadRequest {
    let testdb = TestDb::builtin();
    let case = testdb.get(app).expect("bundled app");
    let mut req =
        OffloadRequest::from_case(case, workloads::source(app).unwrap());
    req.pjrt_sample = None;
    req.with_func_blocks(func_blocks)
}

#[test]
fn tdfir_blocks_strictly_beat_loop_only_on_the_fpga() {
    let b = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();

    let loop_only = pipe.solve(request("tdfir", false)).unwrap();
    let blocked = pipe.solve(request("tdfir", true)).unwrap();

    assert_eq!(loop_only.plan.block_count(), 0);
    assert!(
        blocked.plan.block_count() >= 1,
        "the fir bank must be replaced"
    );
    assert!(blocked.plan.verified_ok());
    assert!(loop_only.plan.verified_ok());
    assert!(
        blocked.plan.speedup() > loop_only.plan.speedup(),
        "blocks {:.3}x must strictly beat loop-only {:.3}x",
        blocked.plan.speedup(),
        loop_only.plan.speedup()
    );

    // Every accepted replacement is sample-test confirmed, and the
    // claimed loops never reappear in the measured loop patterns.
    let sol = blocked.plan.solution().unwrap();
    for block in &sol.blocks {
        assert!(block.confirmed, "{}", block.func);
        for m in &sol.measurements {
            for l in &m.loops {
                assert!(
                    !block.loops.contains(l),
                    "claimed loop {l} was measured as a loop pattern"
                );
            }
        }
    }
    // The fir bank's own nest (L12..L15) is claimed.
    let fir = sol.blocks.iter().find(|b| b.func == "fir_all").unwrap();
    assert_eq!(
        fir.loops.iter().map(|l| l.0).collect::<Vec<_>>(),
        vec![12, 13, 14, 15]
    );
}

#[test]
fn every_bundled_app_solves_with_blocks_enabled() {
    let b = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
    for app in workloads::APPS {
        let loop_only = pipe.solve(request(app, false)).unwrap();
        let blocked = pipe.solve(request(app, true)).unwrap();
        assert!(blocked.plan.verified_ok(), "{app}");
        // Blocks may or may not be profitable per app/destination, but
        // they must not make the combined plan worse than loop-only: an
        // unprofitable block is simply not planned, and the blocks-only
        // (empty loop pattern) plan is always selectable.
        assert!(
            blocked.plan.speedup() >= loop_only.plan.speedup() * 0.999,
            "{app}: blocks regressed {:.3}x -> {:.3}x",
            loop_only.plan.speedup(),
            blocked.plan.speedup()
        );
    }
}

/// Structurally FIR-shaped, behaviorally a saturating accumulator: the
/// detector proposes it, the sample test must reject it, and the
/// pipeline must solve the program loop-only.
const SAT_FIR_SRC: &str = "
#define M 4
#define K 8
#define N 64
#define NIN 71
float cr[M][K]; float ci[M][K];
float xr[NIN]; float xi[NIN];
float outr[M][N]; float outi[M][N];
void fir_sat() {
    for (int m = 0; m < M; m++) {
        for (int n = 0; n < N; n++) {
            float ar = 0.0;
            float ai = 0.0;
            for (int k = 0; k < K; k++) {
                ar += cr[m][k] * xr[n + k] - ci[m][k] * xi[n + k];
                ai += cr[m][k] * xi[n + k] + ci[m][k] * xr[n + k];
                ar = fmin(ar, 0.5);
            }
            outr[m][n] = ar;
            outi[m][n] = ai;
        }
    }
}
int main() { fir_sat(); return 0; }";

#[test]
fn semantically_different_lookalike_is_never_replaced() {
    let b = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
    let req = OffloadRequest::builder("satfir")
        .source(SAT_FIR_SRC)
        .func_blocks(true)
        .build()
        .unwrap();
    let planned = pipe.solve(req).unwrap();
    assert_eq!(
        planned.plan.block_count(),
        0,
        "saturating FIR must never be swapped for the catalog core"
    );
    // The program still offloads through the ordinary loop funnel.
    assert!(planned.plan.verified_ok());
    assert!(!planned.plan.best_loops().is_empty());
}

#[test]
fn mixed_batch_routes_on_combined_block_plus_loop_speedup() {
    let fpga = fpga_backend();
    let gpu = GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    };
    let cpu = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
    let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
    let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();
    let report = Batch::mixed(vec![&pf, &pg, &pc])
        .with(request("tdfir", true))
        .with(request("sobel", true))
        .run();
    assert_eq!(report.solved(), 2);
    for entry in &report.entries {
        let plan = entry.plan.as_ref().unwrap();
        assert!(plan.verified_ok(), "{}", entry.app);
        // The winner's combined speedup dominates every destination's.
        for o in &entry.outcomes {
            if let Some(p) = &o.plan {
                assert!(
                    plan.speedup() >= p.speedup() - 1e-12,
                    "{}: winner {:.3}x < {} {:.3}x",
                    entry.app,
                    plan.speedup(),
                    o.backend,
                    p.speedup()
                );
            }
        }
        // The control never carries a block replacement.
        let cpu_outcome = entry
            .outcomes
            .iter()
            .find(|o| o.backend == "cpu")
            .unwrap();
        if let Some(p) = &cpu_outcome.plan {
            assert_eq!(p.block_count(), 0, "{}", entry.app);
            assert!((p.speedup() - 1.0).abs() < 1e-9);
        }
    }
    // tdfir's FPGA outcome carries the fir-bank replacement.
    let tdfir = &report.entries[0];
    let fpga_outcome = tdfir
        .outcomes
        .iter()
        .find(|o| o.backend == "fpga")
        .unwrap();
    assert!(fpga_outcome.plan.as_ref().unwrap().block_count() >= 1);
}
