//! Differential property test: the slot-resolved bytecode VM against
//! the tree-walking oracle.
//!
//! Random MiniC programs (loops, nests, whiles, ifs, user calls,
//! builtins, int/float mixing, compound assignment, printf, casts) are
//! executed on both engines; the runs must agree on
//!
//! * the entry function's return value (bitwise for floats),
//! * the final contents of every global (arrays bitwise),
//! * the total [`OpCounts`], and
//! * every per-loop profile (entries, trips, subtree ops, array
//!   footprints),
//!
//! or both must fail with the same runtime error. This is the contract
//! that lets the VM replace the interpreter on the profiling /
//! verification hot paths without changing any downstream decision.
//!
//! Every program runs against the oracle under *three* VM encodings
//! (§PGO): the default fused-superinstruction encoding, the unfused
//! baseline, and the register-operand experiment — so each fused
//! handler (`MacLocal`, `LoadIndexLocal`, `StoreIndexLocal`,
//! `LoadIndexBin`, `BinConstInt`, `CompoundLocalConst`, `CmpConstJump`,
//! `BinLocal`) is differentially pinned on the same corpus.
//!
//! Corpus size and seed come from `VM_FUZZ_CASES` / `VM_FUZZ_SEED`
//! (defaults: 1000 programs, fixed seed — CI pins both for
//! reproducible runs).

use std::collections::BTreeSet;

use fpga_offload::minic::ast::Stmt;
use fpga_offload::minic::{
    parse, Engine, Interp, OpCounts, ResolveOpts, Value, Vm,
};
use fpga_offload::util::prop::{int_in, weighted};
use fpga_offload::util::rng::Pcg32;

// ---- random program generator ----

struct Gen<'r> {
    rng: &'r mut Pcg32,
    src: String,
    /// Active counted-loop variables (name, exclusive bound).
    loop_vars: Vec<(String, i64)>,
    next_tmp: usize,
    depth: usize,
}

const PRELUDE: &str = "\
#define N 16
#define M 4
float ga[N];
float gb[N];
float gm[M][M];
int gi[N];
float acc;
int cnt;
float lim = 2.5;
float mix(float u, float v) { return u * 0.5 + v * 0.25; }
float clampf(float v) { return fmin(fmax(v, -8.0), 8.0); }
int main() {
    float lacc = 0.0;
    int lcnt = 0;
";

impl<'r> Gen<'r> {
    fn new(rng: &'r mut Pcg32) -> Self {
        Gen {
            rng,
            src: String::from(PRELUDE),
            loop_vars: Vec::new(),
            next_tmp: 0,
            depth: 0,
        }
    }

    fn finish(mut self) -> String {
        // Fold the local accumulators into the result so divergence in
        // any fused local-op handler is observable.
        self.src
            .push_str("    return cnt + lcnt + (int) lacc;\n}\n");
        self.src
    }

    fn indent(&self) -> String {
        "    ".repeat(self.depth + 1)
    }

    /// Index expression guaranteed in `[0, bound)`.
    fn index(&mut self, bound: i64) -> String {
        if !self.loop_vars.is_empty() && self.rng.chance(0.7) {
            let (v, b) = self.loop_vars[self.rng.index(self.loop_vars.len())].clone();
            if b <= bound && self.rng.chance(0.6) {
                return v;
            }
            let off = int_in(self.rng, 0, bound);
            return format!("({v} + {off}) % {bound}");
        }
        int_in(self.rng, 0, bound).to_string()
    }

    /// Integer-valued expression (safe: no division).
    fn iexpr(&mut self, depth: usize) -> String {
        let more = depth < 2;
        match weighted(
            self.rng,
            &[3, 2, 2, if more { 3 } else { 0 }, if more { 2 } else { 0 }, 1],
        ) {
            0 => int_in(self.rng, 0, 8).to_string(),
            1 => "cnt".to_string(),
            2 => {
                if self.loop_vars.is_empty() {
                    int_in(self.rng, 0, 8).to_string()
                } else {
                    self.loop_vars[self.rng.index(self.loop_vars.len())]
                        .0
                        .clone()
                }
            }
            3 => {
                let a = self.iexpr(depth + 1);
                let b = self.iexpr(depth + 1);
                let op = *self.rng.choose(&["+", "-", "*"]);
                format!("({a} {op} {b})")
            }
            4 => {
                let a = self.iexpr(depth + 1);
                let m = int_in(self.rng, 2, 9);
                format!("({a} % {m})")
            }
            _ => {
                let i = self.index(16);
                format!("gi[{i}]")
            }
        }
    }

    /// Float-valued expression (safe: divisions guarded).
    fn fexpr(&mut self, depth: usize) -> String {
        let more = depth < 3;
        match weighted(
            self.rng,
            &[
                3,                       // literal
                2,                       // acc / lim
                2,                       // array read
                1,                       // 2-D array read
                1,                       // int in float context
                if more { 4 } else { 0 }, // binary
                if more { 2 } else { 0 }, // builtin1
                if more { 1 } else { 0 }, // fmin/fmax
                if more { 1 } else { 0 }, // user call
                if more { 1 } else { 0 }, // guarded division
                1,                       // cast
            ],
        ) {
            0 => format!("{:.3}", (int_in(self.rng, -40, 40) as f64) * 0.125),
            1 => (*self.rng.choose(&["acc", "lim"])).to_string(),
            2 => {
                let arr = *self.rng.choose(&["ga", "gb"]);
                let i = self.index(16);
                format!("{arr}[{i}]")
            }
            3 => {
                let i = self.index(4);
                let j = self.index(4);
                format!("gm[{i}][{j}]")
            }
            4 => {
                let e = self.iexpr(depth + 1);
                format!("({e} * 0.25)")
            }
            5 => {
                let a = self.fexpr(depth + 1);
                let b = self.fexpr(depth + 1);
                let op = *self.rng.choose(&["+", "-", "*"]);
                format!("({a} {op} {b})")
            }
            6 => {
                let f = *self.rng.choose(&["sin", "cos", "fabs", "floor"]);
                let a = self.fexpr(depth + 1);
                if f == "sin" && self.rng.chance(0.3) {
                    format!("sqrt(fabs({a}))")
                } else {
                    format!("{f}({a})")
                }
            }
            7 => {
                let f = *self.rng.choose(&["fmin", "fmax"]);
                let a = self.fexpr(depth + 1);
                let b = self.fexpr(depth + 1);
                format!("{f}({a}, {b})")
            }
            8 => {
                let f = *self.rng.choose(&["mix", "clampf"]);
                let a = self.fexpr(depth + 1);
                if f == "mix" {
                    let b = self.fexpr(depth + 1);
                    format!("mix({a}, {b})")
                } else {
                    format!("clampf({a})")
                }
            }
            9 => {
                let a = self.fexpr(depth + 1);
                let b = self.fexpr(depth + 1);
                format!("({a} / (fabs({b}) + 1.5))")
            }
            _ => {
                let e = self.iexpr(depth + 1);
                format!("((float) {e})")
            }
        }
    }

    fn cond(&mut self) -> String {
        let a = self.fexpr(2);
        let b = self.fexpr(2);
        let op = *self.rng.choose(&["<", ">", "<=", ">=", "==", "!="]);
        if self.rng.chance(0.25) {
            let c = self.fexpr(2);
            let logic = *self.rng.choose(&["&&", "||"]);
            format!("{a} {op} {b} {logic} {c} < 3.0")
        } else {
            format!("{a} {op} {b}")
        }
    }

    fn stmt(&mut self) {
        let nested_ok = self.depth < 3;
        match weighted(
            self.rng,
            &[
                4, // array store
                3, // scalar update
                2, // if
                if nested_ok { 3 } else { 0 }, // for loop
                if nested_ok { 1 } else { 0 }, // while loop
                1, // local temp + use
                1, // printf / bare call
            ],
        ) {
            0 => self.array_store(),
            1 => self.scalar_update(),
            2 => self.if_stmt(),
            3 => self.for_loop(),
            4 => self.while_loop(),
            5 => {
                let t = format!("t{}", self.next_tmp);
                self.next_tmp += 1;
                let e = self.fexpr(1);
                let ind = self.indent();
                self.src.push_str(&format!("{ind}float {t} = {e};\n"));
                self.src
                    .push_str(&format!("{ind}acc += {t} * 0.5;\n"));
            }
            _ => {
                let ind = self.indent();
                if self.rng.chance(0.5) {
                    let e = self.fexpr(1);
                    self.src.push_str(&format!(
                        "{ind}printf(\"v=%f\\n\", {e});\n"
                    ));
                } else {
                    let a = self.fexpr(1);
                    let b = self.fexpr(1);
                    self.src
                        .push_str(&format!("{ind}mix({a}, {b});\n"));
                }
            }
        }
    }

    fn array_store(&mut self) {
        let ind = self.indent();
        let op = *self.rng.choose(&["=", "+=", "-=", "*="]);
        match self.rng.index(4) {
            0 => {
                let i = self.index(16);
                let e = self.fexpr(0);
                self.src.push_str(&format!("{ind}ga[{i}] {op} {e};\n"));
            }
            1 => {
                let i = self.index(16);
                let e = self.fexpr(0);
                self.src.push_str(&format!("{ind}gb[{i}] {op} {e};\n"));
            }
            2 => {
                let i = self.index(4);
                let j = self.index(4);
                let e = self.fexpr(0);
                self.src
                    .push_str(&format!("{ind}gm[{i}][{j}] {op} {e};\n"));
            }
            _ => {
                let i = self.index(16);
                let e = self.iexpr(0);
                self.src.push_str(&format!("{ind}gi[{i}] {op} {e};\n"));
            }
        }
    }

    fn scalar_update(&mut self) {
        let ind = self.indent();
        match self.rng.index(5) {
            0 => {
                let e = self.fexpr(0);
                let op = *self.rng.choose(&["=", "+=", "*="]);
                self.src.push_str(&format!("{ind}acc {op} {e};\n"));
            }
            1 => {
                let e = self.iexpr(0);
                self.src.push_str(&format!("{ind}cnt += {e};\n"));
            }
            2 => {
                // Local MAC shape (fuses to `MacLocal`).
                let a = self.fexpr(1);
                let b = self.fexpr(1);
                self.src
                    .push_str(&format!("{ind}lacc += {a} * {b};\n"));
            }
            3 => {
                // Local compound with an int immediate (fuses to
                // `CompoundLocalConst`).
                if self.rng.chance(0.5) {
                    let c = int_in(self.rng, 1, 5);
                    self.src.push_str(&format!("{ind}lcnt += {c};\n"));
                } else {
                    self.src.push_str(&format!("{ind}lcnt++;\n"));
                }
            }
            _ => {
                self.src.push_str(&format!("{ind}cnt++;\n"));
            }
        }
    }

    fn if_stmt(&mut self) {
        let c = self.cond();
        let ind = self.indent();
        self.src.push_str(&format!("{ind}if ({c}) {{\n"));
        self.depth += 1;
        self.stmt();
        self.depth -= 1;
        if self.rng.chance(0.5) {
            self.src.push_str(&format!("{ind}}} else {{\n"));
            self.depth += 1;
            self.stmt();
            self.depth -= 1;
        }
        self.src.push_str(&format!("{ind}}}\n"));
    }

    fn for_loop(&mut self) {
        let v = format!("i{}", self.loop_vars.len());
        let bound = int_in(self.rng, 1, 11);
        let ind = self.indent();
        self.src.push_str(&format!(
            "{ind}for (int {v} = 0; {v} < {bound}; {v}++) {{\n"
        ));
        self.loop_vars.push((v, bound));
        self.depth += 1;
        for _ in 0..(1 + self.rng.index(3)) {
            self.stmt();
        }
        self.depth -= 1;
        self.loop_vars.pop();
        self.src.push_str(&format!("{ind}}}\n"));
    }

    fn while_loop(&mut self) {
        let w = format!("w{}", self.next_tmp);
        self.next_tmp += 1;
        let bound = int_in(self.rng, 1, 6);
        let ind = self.indent();
        self.src
            .push_str(&format!("{ind}int {w} = {bound};\n"));
        self.src.push_str(&format!("{ind}while ({w} > 0) {{\n"));
        self.depth += 1;
        self.stmt();
        let ind2 = self.indent();
        self.src.push_str(&format!("{ind2}{w} = {w} - 1;\n"));
        self.depth -= 1;
        self.src.push_str(&format!("{ind}}}\n"));
    }
}

fn gen_program(rng: &mut Pcg32) -> String {
    let n = 3 + rng.index(6);
    let mut g = Gen::new(rng);
    for _ in 0..n {
        g.stmt();
    }
    g.finish()
}

// ---- observation + comparison ----

/// Everything observable about one run, normalized for comparison
/// (floats bitwise, footprint sets ordered).
#[derive(Debug, PartialEq, Eq)]
struct Observed {
    result: (u8, u64),
    total: OpCounts,
    loops: Vec<(u32, u64, u64, OpCounts, BTreeSet<String>, BTreeSet<String>)>,
    arrays: Vec<(String, Vec<u64>)>,
    scalars: Vec<(String, u64)>,
}

fn value_key(v: &Value) -> (u8, u64) {
    match v {
        Value::Int(i) => (0, *i as u64),
        Value::Float(f) => (1, f.to_bits()),
        Value::Array(r) => (2, r.0 as u64),
    }
}

fn observe(
    eng: &mut dyn Engine,
    globals: &[(String, bool)],
) -> Result<Observed, String> {
    let r = eng.call("main", &[]).map_err(|e| e.to_string())?;
    let profile = eng.profile();
    let mut loops: Vec<_> = profile
        .loops
        .iter()
        .map(|(id, lp)| {
            (
                id.0,
                lp.entries,
                lp.trips,
                lp.ops,
                lp.arrays_read.iter().cloned().collect::<BTreeSet<_>>(),
                lp.arrays_written.iter().cloned().collect::<BTreeSet<_>>(),
            )
        })
        .collect();
    loops.sort_by_key(|l| l.0);
    let mut arrays = Vec::new();
    let mut scalars = Vec::new();
    for (name, is_array) in globals {
        if *is_array {
            let r = eng
                .global_array(name)
                .ok_or_else(|| format!("missing array {name}"))?;
            arrays.push((
                name.clone(),
                eng.array(r).data.iter().map(|x| x.to_bits()).collect(),
            ));
        } else {
            let v = eng
                .global_scalar(name)
                .ok_or_else(|| format!("missing scalar {name}"))?;
            scalars.push((name.clone(), v.to_bits()));
        }
    }
    Ok(Observed {
        result: value_key(&r),
        total: profile.total,
        loops,
        arrays,
        scalars,
    })
}

fn diff(a: &Observed, b: &Observed) -> Option<String> {
    if a.result != b.result {
        return Some(format!("result {:?} vs {:?}", a.result, b.result));
    }
    if a.total != b.total {
        return Some(format!("totals {:?} vs {:?}", a.total, b.total));
    }
    if a.loops != b.loops {
        return Some(format!("loops {:?} vs {:?}", a.loops, b.loops));
    }
    if a.arrays != b.arrays {
        for ((n1, d1), (_, d2)) in a.arrays.iter().zip(&b.arrays) {
            if d1 != d2 {
                return Some(format!("array {n1} differs"));
            }
        }
        return Some("array set differs".into());
    }
    if a.scalars != b.scalars {
        return Some(format!(
            "scalars {:?} vs {:?}",
            a.scalars, b.scalars
        ));
    }
    None
}

fn engines_agree(src: &str) -> Result<(), String> {
    let prog = parse(src).map_err(|e| format!("parse: {e}"))?;
    let globals: Vec<(String, bool)> = prog
        .globals
        .iter()
        .filter_map(|g| match g {
            Stmt::Decl { name, ty, .. } => {
                Some((name.clone(), ty.is_indexable()))
            }
            _ => None,
        })
        .collect();

    let mut interp = Interp::new(&prog).map_err(|e| e.to_string())?;
    let oracle = observe(&mut interp, &globals);

    for (label, opts) in [
        ("vm", ResolveOpts::default()),
        ("vm-baseline", ResolveOpts::baseline()),
        ("vm-regs", ResolveOpts::regs()),
    ] {
        let mut vm =
            Vm::new_with(&prog, &opts).map_err(|e| e.to_string())?;
        let fast = observe(&mut vm, &globals);
        match (&oracle, fast) {
            (Ok(a), Ok(b)) => {
                if let Some(d) = diff(a, &b) {
                    return Err(format!("{label}: {d}"));
                }
            }
            (Err(a), Err(b)) => {
                if *a != b {
                    return Err(format!(
                        "{label}: different errors: {a:?} vs {b:?}"
                    ));
                }
            }
            (Ok(_), Err(e)) => {
                return Err(format!("{label} failed, oracle passed: {e}"))
            }
            (Err(e), Ok(_)) => {
                return Err(format!("oracle failed, {label} passed: {e}"))
            }
        }
    }
    Ok(())
}

// ---- tests ----

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[test]
fn vm_matches_oracle_on_random_programs() {
    // Seeded fuzz sweep: every program runs on the oracle and all
    // three VM encodings; identical results, globals, OpCounts, and
    // per-loop profiles (or identical errors) required throughout.
    let cases = env_u64("VM_FUZZ_CASES", 1000);
    let seed = env_u64("VM_FUZZ_SEED", 0x5eed_0000);
    let mut divergences = Vec::new();
    for case in 0..cases {
        let mut rng = Pcg32::new(seed.wrapping_add(case), case);
        let src = gen_program(&mut rng);
        if let Err(d) = engines_agree(&src) {
            divergences.push(format!(
                "case {case} (seed {seed}): {d}\n--- program ---\n{src}"
            ));
            if divergences.len() >= 3 {
                break;
            }
        }
    }
    assert!(
        divergences.is_empty(),
        "{} divergence(s) over {cases} programs:\n\n{}",
        divergences.len(),
        divergences.join("\n\n")
    );
}

#[test]
fn vm_matches_oracle_on_bundled_workloads() {
    for app in fpga_offload::workloads::APPS {
        let src = fpga_offload::workloads::source(app).unwrap();
        engines_agree(src).unwrap_or_else(|d| panic!("{app}: {d}"));
    }
}

#[test]
fn vm_matches_oracle_on_error_programs() {
    // Out-of-bounds and div-by-zero must fail identically.
    for src in [
        "#define N 4\nfloat a[N];\nint main() { a[9] = 1.0; return 0; }",
        "int main() { int x = 0; return 3 / x; }",
        "int main() { int x = 0; return 3 % x; }",
        "#define N 4\nfloat a[N];\nint main() { return a[0][1]; }",
        // Faults inside fused handlers: StoreIndexLocal going out of
        // bounds mid-loop, LoadIndexLocal on a read, and an array
        // operand inside a fused compare-and-branch.
        "#define N 4\nfloat a[N];\nint main() { for (int i = 0; i < 9; i++) { a[i] = 1.0; } return 0; }",
        "#define N 4\nfloat a[N];\nint main() { float s = 0.0; for (int i = 0; i < 9; i++) { s += a[i]; } return (int) s; }",
        "#define N 4\nfloat a[N];\nint main() { int n = 0; while (a < 4) { n++; } return n; }",
    ] {
        engines_agree(src).unwrap_or_else(|d| panic!("{src}: {d}"));
    }
}
