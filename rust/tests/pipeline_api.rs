//! Integration: the staged `Pipeline` API, `Batch` orchestration, and
//! the `run_flow` shim.
//!
//! Covers the API-redesign guarantees:
//! * builder validation errors,
//! * stage artifacts flowing parse → analyze → extract → measure →
//!   select → deploy (the typestate itself is enforced at compile time;
//!   see the `compile_fail` doctest on `envadapt::pipeline`),
//! * batch determinism under a fixed seed — a batch entry must equal an
//!   individually-run pipeline solution,
//! * pattern-DB cache reuse keyed on the full reuse key (source hash +
//!   backend + entry + destination device + config fingerprint), and
//!   cache *invalidation* when the device or config changes,
//! * end-to-end offload of a request with a non-`main` entry,
//! * mixed-destination batches routing each app to its best verified
//!   destination (FPGA / GPU / many-core OpenMP / CPU), with solo-run
//!   equivalence per destination,
//! * `run_flow` shim equivalence against the staged pipeline.

#![allow(deprecated)]

use fpga_offload::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use fpga_offload::envadapt::{
    run_flow, Batch, FlowOptions, OffloadRequest, Pipeline, PipelineError,
    TestDb,
};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::{Device, ARRIA10_GX};
use fpga_offload::search::{
    CpuBaseline, FpgaBackend, GpuBackend, OmpBackend, SearchConfig,
};
use fpga_offload::util::tempdir::TempDir;
use fpga_offload::workloads;

const SEED: u64 = 1234;

fn fpga_backend() -> FpgaBackend<'static> {
    FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

fn gpu_backend() -> GpuBackend<'static> {
    GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    }
}

fn omp_backend() -> OmpBackend<'static> {
    OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    }
}

fn cpu_backend() -> CpuBaseline<'static> {
    CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

fn bundled_request(app: &str) -> OffloadRequest {
    let testdb = TestDb::builtin();
    let case = testdb.get(app).expect("bundled app");
    let mut req =
        OffloadRequest::from_case(case, workloads::source(app).unwrap());
    req.seed = SEED;
    req.pjrt_sample = None;
    req
}

#[test]
fn builder_validation_errors_are_typed() {
    assert!(matches!(
        OffloadRequest::builder("x").build(),
        Err(PipelineError::InvalidRequest(_))
    ));
    assert!(matches!(
        OffloadRequest::builder("").source("int main() {}").build(),
        Err(PipelineError::InvalidRequest(_))
    ));
    assert!(matches!(
        Pipeline::new(
            SearchConfig {
                first_round: 0,
                ..Default::default()
            },
            &fpga_backend(),
        ),
        Err(PipelineError::InvalidConfig(_))
    ));
}

#[test]
fn staged_pipeline_runs_all_bundled_apps() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    for app in workloads::APPS {
        let parsed = pipe.parse(bundled_request(app)).unwrap();
        let analyzed = pipe.analyze(parsed).unwrap();
        let candidates = pipe.extract(analyzed).unwrap();
        assert!(!candidates.cands.is_empty(), "{app}: no candidates");
        let measured = pipe.measure(candidates).unwrap();
        let planned = pipe.select(measured).unwrap();
        assert!(
            planned.plan.speedup() > 1.0,
            "{app}: expected acceleration, got {:.2}x",
            planned.plan.speedup()
        );
        let deployed = pipe.deploy(planned, None).unwrap();
        assert_eq!(deployed.backend, "fpga");
    }
}

/// The acceptance check: ≥3 registered workloads through one shared
/// automation cycle, per-app solutions identical to individually-run
/// pipelines under the same seed.
#[test]
fn batch_cycle_matches_individual_pipelines() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();

    let mut batch = Batch::new(&pipe);
    for app in workloads::APPS {
        batch.push(bundled_request(app));
    }
    assert!(batch.len() >= 3, "need tdfir, mriq, sobel at least");
    let report = batch.run();
    assert_eq!(report.solved(), workloads::APPS.len());
    assert_eq!(report.failed(), 0);

    for (app, entry) in workloads::APPS.iter().zip(&report.entries) {
        assert_eq!(&entry.app, app);
        let solo = pipe.solve(bundled_request(app)).unwrap();
        let batch_plan = entry.plan.as_ref().unwrap();
        assert_eq!(
            batch_plan.best_loops(),
            solo.plan.best_loops(),
            "{app}: batch and solo disagree on the pattern"
        );
        assert!(
            (batch_plan.speedup() - solo.plan.speedup()).abs() < 1e-12,
            "{app}: batch and solo disagree on the speedup"
        );
    }

    // Aggregate accounting: concurrent cycle is bounded by the slowest
    // app, serial by the sum.
    assert!(report.concurrent_automation_s <= report.serial_automation_s);
    assert!(report.concurrent_automation_s > 0.0);
}

#[test]
fn batch_report_json_roundtrips_per_app_solutions() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let report = Batch::new(&pipe)
        .with(bundled_request("sobel"))
        .with(bundled_request("mriq"))
        .run();

    let dir = TempDir::new("fpga-offload-batch-json").unwrap();
    let path = dir.join("report.json");
    report.write_json(&path).unwrap();
    let parsed = fpga_offload::util::json::Json::parse(
        &std::fs::read_to_string(&path).unwrap(),
    )
    .unwrap();

    assert_eq!(parsed.get(&["apps"]).unwrap().as_f64(), Some(2.0));
    let results = parsed.get(&["results"]).unwrap().as_arr().unwrap();
    for (entry, j) in report.entries.iter().zip(results) {
        assert_eq!(
            j.get(&["app"]).unwrap().as_str(),
            Some(entry.app.as_str())
        );
        let plan = entry.plan.as_ref().unwrap();
        assert!(
            (j.get(&["speedup"]).unwrap().as_f64().unwrap()
                - plan.speedup())
            .abs()
                < 1e-9
        );
    }
}

#[test]
fn batch_runs_on_the_cpu_baseline_backend() {
    let backend = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let report = Batch::new(&pipe).with(bundled_request("sobel")).run();
    assert_eq!(report.solved(), 1);
    assert_eq!(report.backend, "cpu");
    let plan = report.entries[0].plan.as_ref().unwrap();
    assert_eq!(plan.speedup(), 1.0);
}

#[test]
fn cache_reuse_is_keyed_on_source_hash() {
    let backend = fpga_backend();
    let dir = TempDir::new("fpga-offload-cache-int").unwrap();
    let pipe = Pipeline::new(SearchConfig::default(), &backend)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);

    let fresh = pipe.solve(bundled_request("sobel")).unwrap();
    assert!(!fresh.plan.is_cached());
    let reused = pipe.solve(bundled_request("sobel")).unwrap();
    assert!(reused.plan.is_cached());
    assert_eq!(fresh.plan.best_loops(), reused.plan.best_loops());

    // Same DB, reuse disabled: always a fresh search.
    let no_reuse = Pipeline::new(SearchConfig::default(), &backend)
        .unwrap()
        .with_pattern_db(dir.path());
    assert!(!no_reuse
        .solve(bundled_request("sobel"))
        .unwrap()
        .plan
        .is_cached());
}

/// A workload whose loops live under a non-`main` entry — there is no
/// `main` at all, so the old hard-coded-`"main"` verification would have
/// failed the whole pipeline instead of verifying `run_filter`.
const NON_MAIN_SRC: &str = "
#define N 1024
#define K 8
#define NK 1016
float x[N]; float h[K]; float y[N];
int run_filter() {
    for (int i = 0; i < N; i++) { x[i] = i * 0.003 - 1.4; }
    for (int k = 0; k < K; k++) { h[k] = (k % 3) * 0.2 + 0.1; }
    for (int n = 0; n < NK; n++) {
        float acc = 0.0;
        for (int k = 0; k < K; k++) {
            acc += h[k] * sin(x[n + k]);
        }
        y[n] = acc;
    }
    return 0;
}";

#[test]
fn non_main_entry_offloads_end_to_end() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let req = OffloadRequest::builder("filterbank")
        .source(NON_MAIN_SRC)
        .entry("run_filter")
        .seed(SEED)
        .build()
        .unwrap();
    let planned = pipe.solve(req).unwrap();
    let sol = planned.plan.solution().expect("fresh plan");
    // Every measured pattern was functionally verified — against
    // `run_filter`, the only entry this program has.
    assert!(!sol.measurements.is_empty());
    for m in &sol.measurements {
        assert_eq!(m.verified, Some(true), "{}", m.label());
    }
    assert!(planned.plan.speedup() > 0.5);

    // The same request on the GPU destination also verifies end to end.
    let gpu = gpu_backend();
    let gpipe = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
    let greq = OffloadRequest::builder("filterbank")
        .source(NON_MAIN_SRC)
        .entry("run_filter")
        .seed(SEED)
        .build()
        .unwrap();
    let gplanned = gpipe.solve(greq).unwrap();
    let gsol = gplanned.plan.solution().expect("fresh plan");
    for m in &gsol.measurements {
        assert_eq!(m.verified, Some(true), "gpu {}", m.label());
    }
}

/// The complement of the reuse tests: a stored plan must be *invalidated*
/// when the destination device changes, even though app, source, backend
/// name, entry and config all stay the same.
#[test]
fn cache_invalidated_on_device_change() {
    let dir = TempDir::new("fpga-offload-cache-dev").unwrap();
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);
    assert!(!pipe.solve(bundled_request("sobel")).unwrap().plan.is_cached());
    assert!(pipe.solve(bundled_request("sobel")).unwrap().plan.is_cached());

    // Same backend name ("fpga"), different board.
    let rev_b = Device {
        name: "Intel PAC Arria10 GX 1150 (rev B)",
        ..ARRIA10_GX
    };
    let backend_b = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &rev_b,
    };
    let pipe_b = Pipeline::new(SearchConfig::default(), &backend_b)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);
    let plan_b = pipe_b.solve(bundled_request("sobel")).unwrap();
    assert!(
        !plan_b.plan.is_cached(),
        "a plan searched for one device must not be replayed on another"
    );
}

/// ... and when the search configuration changes.
#[test]
fn cache_invalidated_on_config_change() {
    let dir = TempDir::new("fpga-offload-cache-cfg").unwrap();
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);
    assert!(!pipe.solve(bundled_request("sobel")).unwrap().plan.is_cached());
    assert!(pipe.solve(bundled_request("sobel")).unwrap().plan.is_cached());

    let tighter = SearchConfig {
        max_patterns: 3,
        ..Default::default()
    };
    let pipe_cfg = Pipeline::new(tighter, &backend)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);
    let plan_cfg = pipe_cfg.solve(bundled_request("sobel")).unwrap();
    assert!(
        !plan_cfg.plan.is_cached(),
        "a plan searched under one config must not survive a config change"
    );
}

/// The mixed-destination acceptance check: one cycle over the bundled
/// workloads routes every app to a destination, the FPGA entries are
/// identical to solo FPGA runs, and across the workload set every real
/// destination earns its seat (the tdfir K-tap MAC suits the Arria10's
/// spatialized pipeline; the mriq trig kernel suits the T4's SFUs; the
/// Sobel stencil's light per-pixel work cannot amortize PCIe but
/// parallelizes cleanly over the many-core's shared memory).
#[test]
fn mixed_batch_routes_each_app_to_its_best_destination() {
    let fpga = fpga_backend();
    let gpu = gpu_backend();
    let omp = omp_backend();
    let cpu = cpu_backend();
    let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
    let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
    let po = Pipeline::new(SearchConfig::default(), &omp).unwrap();
    let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();

    let mut batch = Batch::mixed(vec![&pf, &pg, &po, &pc]);
    for app in workloads::APPS {
        batch.push(bundled_request(app));
    }
    let report = batch.run();
    assert!(report.is_mixed());
    assert_eq!(report.backends, vec!["fpga", "gpu", "omp", "cpu"]);
    assert_eq!(report.solved(), workloads::APPS.len());

    let mut best_omp = 0.0f64;
    for (app, entry) in workloads::APPS.iter().zip(&report.entries) {
        assert_eq!(&entry.app, app);
        let dest = entry.destination.expect("every app routed");
        let win = entry.plan.as_ref().unwrap();
        assert!(win.verified_ok(), "{app}: unverified winner");
        // The winner is at least as fast as every other destination.
        for o in &entry.outcomes {
            if let Some(p) = &o.plan {
                assert!(
                    win.speedup() >= p.speedup() - 1e-12,
                    "{app}: {dest} lost to {}",
                    o.backend
                );
                if o.backend == "omp" {
                    best_omp = best_omp.max(p.speedup());
                }
            }
        }
        // Solo-run equivalence on the FPGA destination (outcome 0): the
        // mixed cycle must not perturb single-backend results.
        let fpga_outcome = &entry.outcomes[0];
        assert_eq!(fpga_outcome.backend, "fpga");
        let fpga_plan = fpga_outcome.plan.as_ref().unwrap();
        let solo = pf.solve(bundled_request(app)).unwrap();
        assert_eq!(fpga_plan.best_loops(), solo.plan.best_loops());
        assert!(
            (fpga_plan.speedup() - solo.plan.speedup()).abs() < 1e-12,
            "{app}: mixed fpga outcome differs from solo run"
        );
    }

    let dests: Vec<_> = report
        .entries
        .iter()
        .filter_map(|e| e.destination)
        .collect();
    assert!(
        dests.contains(&"fpga"),
        "no app landed on the FPGA: {dests:?}"
    );
    assert!(
        dests.contains(&"gpu"),
        "no app landed on the GPU: {dests:?}"
    );
    // The many-core destination earns its seat: it wins an app outright
    // or at minimum strictly beats the all-CPU control somewhere.
    assert!(
        dests.contains(&"omp") || best_omp > 1.0,
        "many-core destination is dead weight: {dests:?}, best {best_omp}"
    );
}

/// Solo-vs-mixed equivalence for the many-core destination: a `--backend
/// omp` pipeline run alone produces exactly the plan the mixed cycle's
/// omp outcome carries, for every bundled app.
#[test]
fn omp_solo_matches_mixed_outcome() {
    let fpga = fpga_backend();
    let gpu = gpu_backend();
    let omp = omp_backend();
    let cpu = cpu_backend();
    let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
    let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
    let po = Pipeline::new(SearchConfig::default(), &omp).unwrap();
    let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();

    let mut batch = Batch::mixed(vec![&pf, &pg, &po, &pc]);
    for app in workloads::APPS {
        batch.push(bundled_request(app));
    }
    let report = batch.run();

    for (app, entry) in workloads::APPS.iter().zip(&report.entries) {
        let omp_outcome = entry
            .outcomes
            .iter()
            .find(|o| o.backend == "omp")
            .expect("omp measured");
        let mixed_plan = omp_outcome.plan.as_ref().unwrap();
        let solo = po.solve(bundled_request(app)).unwrap();
        assert_eq!(
            mixed_plan.best_loops(),
            solo.plan.best_loops(),
            "{app}: mixed omp pattern differs from solo --backend omp"
        );
        assert!(
            (mixed_plan.speedup() - solo.plan.speedup()).abs() < 1e-12,
            "{app}: mixed omp speedup differs from solo --backend omp"
        );
        // Every omp measurement was functionally verified.
        let sol = solo.plan.solution().expect("fresh plan");
        for m in &sol.measurements {
            assert_eq!(m.verified, Some(true), "{app} omp {}", m.label());
        }
    }
}

/// ... and when the backend switches between the FPGA and the many-core
/// destination over one shared pattern DB: a plan measured for the
/// Arria10 must never be replayed for the Xeon Gold's OpenMP runtime,
/// and vice versa — while same-backend reuse keeps working on both.
#[test]
fn cache_invalidated_on_fpga_omp_switch() {
    let dir = TempDir::new("fpga-offload-cache-omp").unwrap();
    let fpga = fpga_backend();
    let pipe_f = Pipeline::new(SearchConfig::default(), &fpga)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);
    assert!(!pipe_f
        .solve(bundled_request("sobel"))
        .unwrap()
        .plan
        .is_cached());
    assert!(pipe_f
        .solve(bundled_request("sobel"))
        .unwrap()
        .plan
        .is_cached());

    // Same app, same source, same DB — omp must re-search, then reuse
    // its own record.
    let omp = omp_backend();
    let pipe_o = Pipeline::new(SearchConfig::default(), &omp)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);
    let first_omp = pipe_o.solve(bundled_request("sobel")).unwrap();
    assert!(
        !first_omp.plan.is_cached(),
        "an FPGA plan must not be replayed on the many-core destination"
    );
    assert!(pipe_o
        .solve(bundled_request("sobel"))
        .unwrap()
        .plan
        .is_cached());

    // Switching back: the omp record now owns the slot, so the FPGA
    // pipeline re-searches rather than trusting it.
    assert!(
        !pipe_f
            .solve(bundled_request("sobel"))
            .unwrap()
            .plan
            .is_cached(),
        "an omp plan must not be replayed on the FPGA destination"
    );
}

#[test]
fn run_flow_shim_is_equivalent_to_the_pipeline() {
    let app = "sobel";
    let src = workloads::source(app).unwrap();

    let testdb = TestDb::builtin();
    let opts = FlowOptions {
        config: SearchConfig::default(),
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
        pattern_db: None,
        runtime: None,
        seed: SEED,
    };
    let report = run_flow(app, src, &testdb, &opts).unwrap();

    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let planned = pipe.solve(bundled_request(app)).unwrap();
    let sol = planned.plan.solution().unwrap();

    assert_eq!(
        report.solution.best_measurement().loops,
        sol.best_measurement().loops
    );
    assert!((report.solution.speedup() - sol.speedup()).abs() < 1e-12);
    assert_eq!(
        report.solution.measurements.len(),
        sol.measurements.len()
    );
    assert!(
        (report.solution.automation_s - sol.automation_s).abs() < 1e-9
    );
}
