//! Integration: the staged `Pipeline` API, `Batch` orchestration, and
//! the `run_flow` shim.
//!
//! Covers the API-redesign guarantees:
//! * builder validation errors,
//! * stage artifacts flowing parse → analyze → extract → measure →
//!   select → deploy (the typestate itself is enforced at compile time;
//!   see the `compile_fail` doctest on `envadapt::pipeline`),
//! * batch determinism under a fixed seed — a batch entry must equal an
//!   individually-run pipeline solution,
//! * pattern-DB cache reuse keyed on the source hash,
//! * `run_flow` shim equivalence against the staged pipeline.

#![allow(deprecated)]

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{
    run_flow, Batch, FlowOptions, OffloadRequest, Pipeline, PipelineError,
    TestDb,
};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{CpuBaseline, FpgaBackend, SearchConfig};
use fpga_offload::util::tempdir::TempDir;
use fpga_offload::workloads;

const SEED: u64 = 1234;

fn fpga_backend() -> FpgaBackend<'static> {
    FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

fn bundled_request(app: &str) -> OffloadRequest {
    let testdb = TestDb::builtin();
    let case = testdb.get(app).expect("bundled app");
    let mut req =
        OffloadRequest::from_case(case, workloads::source(app).unwrap());
    req.seed = SEED;
    req.pjrt_sample = None;
    req
}

#[test]
fn builder_validation_errors_are_typed() {
    assert!(matches!(
        OffloadRequest::builder("x").build(),
        Err(PipelineError::InvalidRequest(_))
    ));
    assert!(matches!(
        OffloadRequest::builder("").source("int main() {}").build(),
        Err(PipelineError::InvalidRequest(_))
    ));
    assert!(matches!(
        Pipeline::new(
            SearchConfig {
                first_round: 0,
                ..Default::default()
            },
            &fpga_backend(),
        ),
        Err(PipelineError::InvalidConfig(_))
    ));
}

#[test]
fn staged_pipeline_runs_all_bundled_apps() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    for app in workloads::APPS {
        let parsed = pipe.parse(bundled_request(app)).unwrap();
        let analyzed = pipe.analyze(parsed).unwrap();
        let candidates = pipe.extract(analyzed).unwrap();
        assert!(!candidates.cands.is_empty(), "{app}: no candidates");
        let measured = pipe.measure(candidates).unwrap();
        let planned = pipe.select(measured).unwrap();
        assert!(
            planned.plan.speedup() > 1.0,
            "{app}: expected acceleration, got {:.2}x",
            planned.plan.speedup()
        );
        let deployed = pipe.deploy(planned, None).unwrap();
        assert_eq!(deployed.backend, "fpga");
    }
}

/// The acceptance check: ≥3 registered workloads through one shared
/// automation cycle, per-app solutions identical to individually-run
/// pipelines under the same seed.
#[test]
fn batch_cycle_matches_individual_pipelines() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();

    let mut batch = Batch::new(&pipe);
    for app in workloads::APPS {
        batch.push(bundled_request(app));
    }
    assert!(batch.len() >= 3, "need tdfir, mriq, sobel at least");
    let report = batch.run();
    assert_eq!(report.solved(), workloads::APPS.len());
    assert_eq!(report.failed(), 0);

    for (app, entry) in workloads::APPS.iter().zip(&report.entries) {
        assert_eq!(&entry.app, app);
        let solo = pipe.solve(bundled_request(app)).unwrap();
        let batch_plan = entry.plan.as_ref().unwrap();
        assert_eq!(
            batch_plan.best_loops(),
            solo.plan.best_loops(),
            "{app}: batch and solo disagree on the pattern"
        );
        assert!(
            (batch_plan.speedup() - solo.plan.speedup()).abs() < 1e-12,
            "{app}: batch and solo disagree on the speedup"
        );
    }

    // Aggregate accounting: concurrent cycle is bounded by the slowest
    // app, serial by the sum.
    assert!(report.concurrent_automation_s <= report.serial_automation_s);
    assert!(report.concurrent_automation_s > 0.0);
}

#[test]
fn batch_report_json_roundtrips_per_app_solutions() {
    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let report = Batch::new(&pipe)
        .with(bundled_request("sobel"))
        .with(bundled_request("mriq"))
        .run();

    let dir = TempDir::new("fpga-offload-batch-json").unwrap();
    let path = dir.join("report.json");
    report.write_json(&path).unwrap();
    let parsed = fpga_offload::util::json::Json::parse(
        &std::fs::read_to_string(&path).unwrap(),
    )
    .unwrap();

    assert_eq!(parsed.get(&["apps"]).unwrap().as_f64(), Some(2.0));
    let results = parsed.get(&["results"]).unwrap().as_arr().unwrap();
    for (entry, j) in report.entries.iter().zip(results) {
        assert_eq!(
            j.get(&["app"]).unwrap().as_str(),
            Some(entry.app.as_str())
        );
        let plan = entry.plan.as_ref().unwrap();
        assert!(
            (j.get(&["speedup"]).unwrap().as_f64().unwrap()
                - plan.speedup())
            .abs()
                < 1e-9
        );
    }
}

#[test]
fn batch_runs_on_the_cpu_baseline_backend() {
    let backend = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let report = Batch::new(&pipe).with(bundled_request("sobel")).run();
    assert_eq!(report.solved(), 1);
    assert_eq!(report.backend, "cpu");
    let plan = report.entries[0].plan.as_ref().unwrap();
    assert_eq!(plan.speedup(), 1.0);
}

#[test]
fn cache_reuse_is_keyed_on_source_hash() {
    let backend = fpga_backend();
    let dir = TempDir::new("fpga-offload-cache-int").unwrap();
    let pipe = Pipeline::new(SearchConfig::default(), &backend)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_cache_reuse(true);

    let fresh = pipe.solve(bundled_request("sobel")).unwrap();
    assert!(!fresh.plan.is_cached());
    let reused = pipe.solve(bundled_request("sobel")).unwrap();
    assert!(reused.plan.is_cached());
    assert_eq!(fresh.plan.best_loops(), reused.plan.best_loops());

    // Same DB, reuse disabled: always a fresh search.
    let no_reuse = Pipeline::new(SearchConfig::default(), &backend)
        .unwrap()
        .with_pattern_db(dir.path());
    assert!(!no_reuse
        .solve(bundled_request("sobel"))
        .unwrap()
        .plan
        .is_cached());
}

#[test]
fn run_flow_shim_is_equivalent_to_the_pipeline() {
    let app = "sobel";
    let src = workloads::source(app).unwrap();

    let testdb = TestDb::builtin();
    let opts = FlowOptions {
        config: SearchConfig::default(),
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
        pattern_db: None,
        runtime: None,
        seed: SEED,
    };
    let report = run_flow(app, src, &testdb, &opts).unwrap();

    let backend = fpga_backend();
    let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
    let planned = pipe.solve(bundled_request(app)).unwrap();
    let sol = planned.plan.solution().unwrap();

    assert_eq!(
        report.solution.best_measurement().loops,
        sol.best_measurement().loops
    );
    assert!((report.solution.speedup() - sol.speedup()).abs() < 1e-12);
    assert_eq!(
        report.solution.measurements.len(),
        sol.measurements.len()
    );
    assert!(
        (report.solution.automation_s - sol.automation_s).abs() < 1e-9
    );
}
