//! Kill-point recovery suite for the sharded pattern store.
//!
//! The crash contract under test: an append is a single `write` of a
//! length-prefixed, checksummed frame, so a kill can tear only the
//! *tail* of a shard log. `open()` must then recover every record
//! written before the torn one, truncate the torn bytes, and quarantine
//! nothing — a torn tail is not corruption.
//!
//! The sweep truncates a shard log at **every byte boundary of the
//! final record** (from "frame entirely missing" through "one byte
//! short of complete") and re-opens the store cold each time.

use fpga_offload::store::{log, PatternStore};
use fpga_offload::util::tempdir::TempDir;

fn payload(app: &str, speedup: f64) -> Vec<u8> {
    format!(r#"{{"app":"{app}","speedup":{speedup}}}"#).into_bytes()
}

/// `n` app names that all route to the same shard as `seed`, so the
/// whole sweep exercises one log file with multiple prior records.
fn same_shard_apps(dir: &std::path::Path, n: usize) -> Vec<String> {
    let store = PatternStore::open_fresh(dir).unwrap();
    let seed = "kp-0".to_string();
    let target = store.shard_path_of(&seed);
    let mut apps = vec![seed];
    let mut i = 1;
    while apps.len() < n {
        let name = format!("kp-{i}");
        if store.shard_path_of(&name) == target {
            apps.push(name);
        }
        i += 1;
    }
    apps
}

#[test]
fn truncation_at_every_byte_of_the_final_record_loses_nothing_else() {
    let dir = TempDir::new("store-killpoints").unwrap();
    let apps = same_shard_apps(dir.path(), 4);
    let shard_path = {
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        store.shard_path_of(&apps[0])
    };

    for (i, app) in apps.iter().enumerate() {
        log::append(&shard_path, &payload(app, i as f64 + 1.0)).unwrap();
    }
    let full = std::fs::read(&shard_path).unwrap();
    let last_frame =
        log::FRAME_HEADER + payload(&apps[3], 4.0).len();
    let prior_len = full.len() - last_frame;

    // Every kill point inside the final record's frame, including the
    // boundary where the frame is missing entirely.
    for cut in prior_len..full.len() {
        std::fs::write(&shard_path, &full[..cut]).unwrap();
        let store = PatternStore::open_fresh(dir.path()).unwrap();

        // All prior records recovered, the torn one gone, none lost.
        assert_eq!(
            store.len(),
            3,
            "cut at byte {cut}: wrong live record count"
        );
        for (i, app) in apps.iter().take(3).enumerate() {
            let rec = store.get(app).unwrap_or_else(|| {
                panic!("cut at byte {cut}: lost record {app}")
            });
            assert_eq!(rec.speedup, i as f64 + 1.0);
        }
        assert!(store.get(&apps[3]).is_none());

        // A torn tail is truncated, never quarantined.
        assert_eq!(
            store.quarantined().unwrap(),
            Vec::<String>::new(),
            "cut at byte {cut}: torn tail was quarantined"
        );
        let snap = store.stats().snapshot();
        if cut > prior_len {
            assert_eq!(
                snap.torn_truncations, 1,
                "cut at byte {cut}: torn tail not counted"
            );
        }
        assert_eq!(snap.quarantined_bytes, 0);

        // The repair is durable: the file now ends exactly at the last
        // complete record, so the next open is clean.
        let repaired = std::fs::read(&shard_path).unwrap();
        assert_eq!(
            repaired,
            &full[..prior_len],
            "cut at byte {cut}: file not repaired to the record boundary"
        );
        let reopened = PatternStore::open_fresh(dir.path()).unwrap();
        assert_eq!(reopened.len(), 3);
        assert_eq!(reopened.stats().snapshot().torn_truncations, 0);
    }
}

#[test]
fn append_after_torn_tail_repair_roundtrips() {
    let dir = TempDir::new("store-kill-append").unwrap();
    let apps = same_shard_apps(dir.path(), 2);
    let shard_path = {
        let store = PatternStore::open_fresh(dir.path()).unwrap();
        store.shard_path_of(&apps[0])
    };
    log::append(&shard_path, &payload(&apps[0], 1.0)).unwrap();
    log::append(&shard_path, &payload(&apps[1], 2.0)).unwrap();

    // Tear the final record mid-payload, recover, then write again
    // through the repaired log.
    let full = std::fs::read(&shard_path).unwrap();
    std::fs::write(&shard_path, &full[..full.len() - 7]).unwrap();
    let store = PatternStore::open_fresh(dir.path()).unwrap();
    assert_eq!(store.len(), 1);
    log::append(&shard_path, &payload(&apps[1], 5.0)).unwrap();

    let reopened = PatternStore::open_fresh(dir.path()).unwrap();
    assert_eq!(reopened.len(), 2);
    assert_eq!(reopened.get(&apps[1]).unwrap().speedup, 5.0);
    assert_eq!(reopened.stats().snapshot().torn_truncations, 0);
}
