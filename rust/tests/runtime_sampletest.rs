//! Integration: the PJRT runtime executing the AOT artifacts (Pallas
//! kernels inside JAX models, lowered to HLO text at build time).
//!
//! Requires `make artifacts`. If the artifacts are missing these tests
//! fail with an actionable message rather than being skipped — the
//! end-to-end stack is a deliverable, not an option.
//!
//! Gated behind the `pjrt-live` feature: the offline build ships a stub
//! `xla` crate (rust/vendor/xla) with no real PJRT client, so these
//! tests only make sense once the real binding is wired in.
#![cfg(feature = "pjrt-live")]

use fpga_offload::runtime::{run_mriq, run_tdfir, Artifacts, Runtime};

fn setup() -> (Runtime, Artifacts) {
    let cwd = std::env::current_dir().expect("cwd");
    let art = Artifacts::discover(&cwd)
        .expect("artifacts/ not found — run `make artifacts` first");
    let rt = Runtime::cpu().expect("PJRT CPU client");
    (rt, art)
}

#[test]
fn tdfir_artifact_matches_rust_reference() {
    let (rt, art) = setup();
    let run = run_tdfir(&rt, &art, 42).expect("tdfir sample test");
    assert_eq!(run.app, "tdfir");
    assert!(run.max_abs_err < 5e-3, "err {}", run.max_abs_err);
    assert_eq!(
        run.checked,
        2 * art.tdfir_shape.m * art.tdfir_shape.n,
        "all outputs compared"
    );
}

#[test]
fn mriq_artifact_matches_rust_reference() {
    let (rt, art) = setup();
    let run = run_mriq(&rt, &art, 42).expect("mriq sample test");
    assert_eq!(run.app, "mriq");
    assert!(run.max_abs_err < 5e-2, "err {}", run.max_abs_err);
    assert_eq!(run.checked, 2 * art.mriq_shape.x);
}

#[test]
fn different_seeds_give_different_data_same_correctness() {
    let (rt, art) = setup();
    for seed in [1u64, 7, 1234] {
        let run = run_tdfir(&rt, &art, seed)
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert!(run.max_abs_err < 5e-3, "seed {seed}: {}", run.max_abs_err);
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let (rt, art) = setup();
    // First load compiles; second load must be cache-hit (same pointer).
    let a = rt.load(&art.tdfir_hlo).unwrap();
    let b = rt.load(&art.tdfir_hlo).unwrap();
    assert!(std::ptr::eq(a, b), "executable cache miss");
    // Repeated execution through the cached executable stays correct.
    let r1 = run_tdfir(&rt, &art, 5).unwrap();
    let r2 = run_tdfir(&rt, &art, 5).unwrap();
    assert_eq!(r1.checked, r2.checked);
}

#[test]
fn meta_shapes_match_compiled_artifacts() {
    let (rt, art) = setup();
    // Executing with meta.json's shapes must succeed — i.e. the artifact
    // and its metadata were produced by the same AOT run.
    assert!(run_tdfir(&rt, &art, 2).is_ok());
    assert!(run_mriq(&rt, &art, 2).is_ok());
    assert_eq!(art.tdfir_shape.m * art.tdfir_shape.n > 0, true);
}
