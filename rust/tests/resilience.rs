//! Integration: the fault-tolerant automation cycle end to end — typed
//! faults, retry budgets, seeded injection, and the graceful-degradation
//! ladder (reroute → stale cached plan → all-CPU baseline).

use fpga_offload::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use fpga_offload::envadapt::{
    Batch, OffloadRequest, Pipeline, ServiceLevel,
};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    FaultClass, FaultPlan, FaultyBackend, FpgaBackend, OmpBackend,
    RetryPolicy, SearchConfig, SimClock,
};
use fpga_offload::util::tempdir::TempDir;

const SRC: &str = "
#define N 1024
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

fn fpga() -> FpgaBackend<'static> {
    FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

fn omp() -> OmpBackend<'static> {
    OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    }
}

fn req(app: &str) -> OffloadRequest {
    OffloadRequest::builder(app)
        .source(SRC)
        .seed(7)
        .build()
        .unwrap()
}

/// Transient bursts within the retry budget recover to *exactly* the
/// plan a fault-free cycle produces — retries change telemetry, not
/// results.
#[test]
fn transient_faults_recover_to_the_fault_free_plan() {
    let clean_backend = fpga();
    let clean_pipe =
        Pipeline::new(SearchConfig::default(), &clean_backend).unwrap();
    let clean = Batch::new(&clean_pipe).with(req("app")).run();

    let inner = fpga();
    let clock = SimClock::new();
    let faulty =
        FaultyBackend::new(&inner, FaultPlan::transient_only(11), clock.clone());
    let pipe = Pipeline::new(SearchConfig::default(), &faulty)
        .unwrap()
        .with_retry(RetryPolicy::default())
        .unwrap()
        .with_clock(clock.clone());
    let report = Batch::new(&pipe).with(req("app")).run();

    assert_eq!(report.solved(), 1);
    let entry = &report.entries[0];
    assert_eq!(entry.service, ServiceLevel::Full);
    assert!(entry.degradation.is_none());
    let plan = entry.plan.as_ref().unwrap();
    let clean_plan = clean.entries[0].plan.as_ref().unwrap();
    assert_eq!(plan.best_loops(), clean_plan.best_loops());
    assert!((plan.speedup() - clean_plan.speedup()).abs() < 1e-12);
    // The faults were real: retries happened and backoff burned virtual
    // time on the shared clock.
    assert!(report.fault_telemetry.total_retries() > 0);
    assert!(clock.now_s() > 0.0);
}

/// A destination that fails permanently drops out; the app reroutes to
/// its next-best surviving destination and the entry says why.
#[test]
fn permanently_failing_destination_reroutes_to_next_best() {
    let fpga_inner = fpga();
    let omp_backend = omp();
    let clock = SimClock::new();
    let broken = FaultyBackend::new(
        &fpga_inner,
        FaultPlan {
            permanent_rate: 1.0,
            ..FaultPlan::none()
        },
        clock.clone(),
    );
    let pf = Pipeline::new(SearchConfig::default(), &broken)
        .unwrap()
        .with_retry(RetryPolicy::default())
        .unwrap()
        .with_clock(clock.clone());
    let po = Pipeline::new(SearchConfig::default(), &omp_backend).unwrap();
    let report = Batch::mixed(vec![&pf, &po]).with(req("app")).run();

    assert_eq!(report.solved(), 1);
    assert_eq!(report.degraded(), 1);
    let entry = &report.entries[0];
    assert_eq!(entry.destination, Some("omp"));
    assert_eq!(entry.service, ServiceLevel::Rerouted);
    let why = entry.degradation.as_ref().unwrap();
    assert!(why.contains("fpga"), "{why}");
    // The dropped destination carries its typed fault.
    let fault = entry.outcomes[0].error.as_ref().unwrap();
    assert_eq!(fault.class, FaultClass::Permanent);
    // Permanent faults fail fast: no retry budget was spent on them.
    assert_eq!(fault.attempts, 1);
}

/// When every destination fails but the pattern DB still holds a
/// verified plan for the unchanged source, the cycle serves that stale
/// plan instead of leaving the app unserved.
#[test]
fn all_destinations_failing_serve_the_stale_cached_plan() {
    let dir = TempDir::new("fpga-offload-resilience-stale").unwrap();

    // A healthy earlier cycle stores the plan.
    let healthy = fpga();
    let store_pipe = Pipeline::new(SearchConfig::default(), &healthy)
        .unwrap()
        .with_pattern_db(dir.path());
    store_pipe.solve(req("app")).unwrap();

    // Today every destination is broken.
    let inner = fpga();
    let clock = SimClock::new();
    let broken = FaultyBackend::new(
        &inner,
        FaultPlan {
            permanent_rate: 1.0,
            ..FaultPlan::none()
        },
        clock.clone(),
    );
    let pipe = Pipeline::new(SearchConfig::default(), &broken)
        .unwrap()
        .with_pattern_db(dir.path())
        .with_retry(RetryPolicy::default())
        .unwrap()
        .with_clock(clock);
    let report = Batch::new(&pipe).with(req("app")).run();

    assert_eq!(report.served(), 1);
    let entry = &report.entries[0];
    assert_eq!(entry.service, ServiceLevel::ServedStale);
    assert_eq!(entry.destination, Some("fpga"));
    let plan = entry.plan.as_ref().unwrap();
    assert!(plan.is_cached());
    assert!(plan.speedup() > 1.0);
    assert!(entry.error.is_some(), "the failure is still reported");
    // The report flags the stale serving for tooling.
    let j = report.to_json();
    let r0 = &j.get(&["results"]).unwrap().as_arr().unwrap()[0];
    assert_eq!(r0.get(&["served_stale"]).unwrap().as_bool(), Some(true));
    assert_eq!(
        r0.get(&["service"]).unwrap().as_str(),
        Some("served_stale")
    );
}

/// With no cached plan anywhere, the last rung serves the all-CPU
/// baseline: not solved, but never unserved — and the typed fault
/// explains what happened.
#[test]
fn nothing_cached_degrades_to_the_cpu_baseline() {
    let inner = fpga();
    let clock = SimClock::new();
    let broken = FaultyBackend::new(
        &inner,
        FaultPlan {
            permanent_rate: 1.0,
            ..FaultPlan::none()
        },
        clock.clone(),
    );
    let pipe = Pipeline::new(SearchConfig::default(), &broken)
        .unwrap()
        .with_retry(RetryPolicy::default())
        .unwrap()
        .with_clock(clock);
    let report = Batch::new(&pipe).with(req("app")).run();

    assert_eq!(report.solved(), 0);
    assert_eq!(report.served(), 1);
    assert_eq!(report.degraded(), 1);
    let entry = &report.entries[0];
    assert_eq!(entry.service, ServiceLevel::Baseline);
    assert!(entry.destination.is_none());
    let plan = entry.plan.as_ref().unwrap();
    assert!(plan.is_baseline());
    assert_eq!(plan.speedup(), 1.0);
    assert!(entry.error.as_ref().unwrap().contains("fpga"));
    // The JSON carries the typed per-destination fault.
    let j = report.to_json();
    let r0 = &j.get(&["results"]).unwrap().as_arr().unwrap()[0];
    assert_eq!(r0.get(&["service"]).unwrap().as_str(), Some("baseline"));
    assert_eq!(
        r0.get(&["errors", "fpga", "class"]).unwrap().as_str(),
        Some("permanent")
    );
}

/// Retry wrapping with no faults injected is invisible: the per-app
/// results are identical to an unwrapped cycle and no retries happen.
#[test]
fn fault_free_retry_wrapping_is_transparent() {
    let bf = fpga();
    let bo = omp();
    let plain_f = Pipeline::new(SearchConfig::default(), &bf).unwrap();
    let plain_o = Pipeline::new(SearchConfig::default(), &bo).unwrap();
    let plain = Batch::mixed(vec![&plain_f, &plain_o])
        .with(req("app"))
        .run();

    let clock = SimClock::new();
    let wrapped_f = Pipeline::new(SearchConfig::default(), &bf)
        .unwrap()
        .with_retry(RetryPolicy::default())
        .unwrap()
        .with_clock(clock.clone());
    let wrapped_o = Pipeline::new(SearchConfig::default(), &bo)
        .unwrap()
        .with_retry(RetryPolicy::default())
        .unwrap()
        .with_clock(clock.clone());
    let wrapped = Batch::mixed(vec![&wrapped_f, &wrapped_o])
        .with(req("app"))
        .run();

    // Same results object, byte for byte.
    assert_eq!(
        plain.to_json().get(&["results"]),
        wrapped.to_json().get(&["results"])
    );
    assert_eq!(wrapped.fault_telemetry.total_retries(), 0);
    assert_eq!(wrapped.fault_telemetry.total_panics(), 0);
    // No backoff ever ran, so the virtual clock never moved.
    assert_eq!(clock.now_s(), 0.0);
}

/// Hung builds burn the stage deadline and surface as timeout faults in
/// the batch telemetry — the cycle still ends, degraded not wedged.
#[test]
fn hung_builds_time_out_and_the_cycle_still_ends() {
    let inner = fpga();
    let clock = SimClock::new();
    let hung = FaultyBackend::new(
        &inner,
        FaultPlan {
            hang_rate: 1.0,
            hang_s: 3.0 * 3600.0,
            ..FaultPlan::none()
        },
        clock.clone(),
    );
    let pipe = Pipeline::new(SearchConfig::default(), &hung)
        .unwrap()
        .with_retry(RetryPolicy {
            stage_deadline_s: Some(3600.0),
            ..RetryPolicy::default()
        })
        .unwrap()
        .with_clock(clock.clone());
    let report = Batch::new(&pipe).with(req("app")).run();

    assert_eq!(report.served(), 1);
    assert_eq!(report.entries[0].service, ServiceLevel::Baseline);
    let t = &report.fault_telemetry;
    assert!(
        t.measure.timeouts + t.verify.timeouts > 0,
        "expected timeout faults, got {t:?}"
    );
    assert!(clock.now_s() >= 3.0 * 3600.0);
}

/// The same fault seed produces the same cycle, entry for entry —
/// injection is deterministic under concurrency.
#[test]
fn seeded_fault_cycles_are_reproducible() {
    let run_once = || {
        let inner = fpga();
        let clock = SimClock::new();
        let faulty = FaultyBackend::new(
            &inner,
            FaultPlan::from_seed(99),
            clock.clone(),
        );
        let pipe = Pipeline::new(SearchConfig::default(), &faulty)
            .unwrap()
            .with_retry(RetryPolicy::default())
            .unwrap()
            .with_clock(clock);
        let report = Batch::new(&pipe)
            .with(req("app"))
            .with(req("app2"))
            .run();
        report.to_json().pretty()
    };
    assert_eq!(run_once(), run_once());
}
