//! Property-based tests over the coordinator invariants (routing of loops
//! through the funnel, pattern batching rules, and search-state
//! invariants), using the in-repo property harness (proptest substitute —
//! see Cargo.toml note).
//!
//! Programs are *generated*: random loop nests with varying compute
//! density, so the invariants are exercised over a broad family of
//! applications, not just the bundled three.

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{funnel, search, SearchConfig};
use fpga_offload::util::prop::{check, holds, Outcome};
use fpga_offload::util::rng::Pcg32;

/// Generate a random MiniC program with `n_loops` top-level loops over
/// shared arrays, each with random density/shape.
fn gen_program(rng: &mut Pcg32, n_loops: usize) -> String {
    let mut src = String::from(
        "#define N 256\nfloat a[N]; float b[N]; float c[N];\nfloat acc;\n\
         int main() {\n",
    );
    for i in 0..n_loops {
        let dst = ["b", "c"][rng.index(2)];
        let body = match rng.index(5) {
            0 => format!("{dst}[i] = a[i] * 2.0 + 1.0;"),
            1 => format!("{dst}[i] = sin(a[i]) * cos(a[i]);"),
            2 => format!("{dst}[i] = sqrt(a[i] * a[i] + {i}.0);"),
            3 => "acc += a[i];".to_string(),
            _ => format!("{dst}[i] = a[i] / ({i}.0 + 2.0);"),
        };
        let bound = 1 + rng.index(256);
        src.push_str(&format!(
            "    for (int i = 0; i < {bound}; i++) {{ {body} }}\n"
        ));
    }
    src.push_str("    return 0;\n}\n");
    src
}

fn cfg_for(rng: &mut Pcg32) -> SearchConfig {
    let top_c = 1 + rng.index(3);
    SearchConfig {
        top_a: top_c + rng.index(4),
        top_c,
        first_round: 1 + rng.index(top_c),
        max_patterns: top_c + 1,
        verify_numerics: true,
        ..Default::default()
    }
}

#[test]
fn funnel_stage_sizes_always_monotone() {
    check(40, |rng| {
        let n = 2 + rng.index(8);
        let src = gen_program(rng, n);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let cfg = cfg_for(rng);
        match funnel::run(&prog, &an, &cfg, &ARRIA10_GX) {
            Err(_) => Outcome::Pass, // no candidates is legal
            Ok((cands, trace)) => holds(
                trace.offloadable.len() <= trace.total_loops
                    && trace.top_a.len() <= cfg.top_a
                    && trace.top_a.len() <= trace.offloadable.len()
                    && cands.len() <= cfg.top_c
                    && cands.len() <= trace.top_a.len(),
                format!(
                    "funnel not monotone: {} -> {} -> {} -> {} (cfg {cfg:?})",
                    trace.total_loops,
                    trace.offloadable.len(),
                    trace.top_a.len(),
                    cands.len()
                ),
            ),
        }
    });
}

#[test]
fn funnel_survivors_sorted_by_resource_efficiency() {
    check(40, |rng| {
        let n = 3 + rng.index(6);
        let src = gen_program(rng, n);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        match funnel::run(&prog, &an, &SearchConfig::default(), &ARRIA10_GX) {
            Err(_) => Outcome::Pass,
            Ok((cands, _)) => holds(
                cands.windows(2).all(|w| {
                    w[0].report.resource_efficiency
                        >= w[1].report.resource_efficiency
                }),
                "survivors out of order".to_string(),
            ),
        }
    });
}

#[test]
fn search_never_exceeds_measurement_budget() {
    check(30, |rng| {
        let n = 2 + rng.index(8);
        let src = gen_program(rng, n);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let cfg = cfg_for(rng);
        match search("p", &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX) {
            Err(_) => Outcome::Pass,
            Ok(sol) => holds(
                !sol.measurements.is_empty()
                    && sol.measurements.len() <= cfg.max_patterns,
                format!(
                    "budget violated: {} > {}",
                    sol.measurements.len(),
                    cfg.max_patterns
                ),
            ),
        }
    });
}

#[test]
fn best_is_always_the_argmax_and_verified() {
    check(30, |rng| {
        let n = 2 + rng.index(6);
        let src = gen_program(rng, n);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let cfg = cfg_for(rng);
        match search("p", &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX) {
            Err(_) => Outcome::Pass,
            Ok(sol) => {
                let max = sol
                    .measurements
                    .iter()
                    .map(|m| m.speedup())
                    .fold(f64::MIN, f64::max);
                holds(
                    (sol.speedup() - max).abs() < 1e-12
                        && sol
                            .measurements
                            .iter()
                            .all(|m| m.verified == Some(true)),
                    format!("best {} vs max {max}", sol.speedup()),
                )
            }
        }
    });
}

#[test]
fn combination_patterns_only_from_accelerated_disjoint_singles() {
    check(30, |rng| {
        let n = 3 + rng.index(6);
        let src = gen_program(rng, n);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let cfg = cfg_for(rng);
        match search("p", &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX) {
            Err(_) => Outcome::Pass,
            Ok(sol) => {
                // Every round-2 pattern's loops are a union of round-1
                // winners.
                let winners: Vec<_> = sol
                    .measurements
                    .iter()
                    .filter(|m| m.round == 1 && m.speedup() > 1.0)
                    .flat_map(|m| m.loops.clone())
                    .collect();
                let ok = sol
                    .measurements
                    .iter()
                    .filter(|m| m.round == 2)
                    .all(|m| {
                        m.loops.len() >= 2
                            && m.loops.iter().all(|l| winners.contains(l))
                    });
                holds(ok, "round-2 pattern not built from winners".to_string())
            }
        }
    });
}

#[test]
fn deterministic_given_same_input() {
    check(15, |rng| {
        let n = 2 + rng.index(5);
        let src = gen_program(rng, n);
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let cfg = SearchConfig::default();
        let a = search("p", &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX);
        let b = search("p", &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX);
        match (a, b) {
            (Err(_), Err(_)) => Outcome::Pass,
            (Ok(x), Ok(y)) => holds(
                x.measurements.len() == y.measurements.len()
                    && x.best_measurement().loops
                        == y.best_measurement().loops
                    && (x.speedup() - y.speedup()).abs() < 1e-12,
                "nondeterministic search".to_string(),
            ),
            _ => Outcome::Fail("one run errored".to_string()),
        }
    });
}
