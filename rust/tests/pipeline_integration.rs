//! Integration: the full offload pipeline over every bundled workload —
//! parse → typecheck → profile → funnel → patterns → simulate → verify.

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::{parse, typecheck};
use fpga_offload::search::{search, SearchConfig};
use fpga_offload::workloads;

fn solve(app: &str) -> fpga_offload::search::OffloadSolution {
    let src = workloads::source(app).unwrap();
    let prog = parse(src).unwrap();
    assert!(typecheck::check(&prog).is_empty());
    let an = analyze(&prog, "main").unwrap();
    search(
        app,
        &prog,
        &an,
        &SearchConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    )
    .unwrap()
}

#[test]
fn tdfir_reproduces_fig4_shape() {
    let sol = solve("tdfir");
    assert!(
        (2.5..7.0).contains(&sol.speedup()),
        "tdfir speedup {:.2} out of the paper's ballpark (4.0x)",
        sol.speedup()
    );
    // The winner must be part of the FIR bank nest (L12..L15).
    assert!(sol
        .best_measurement()
        .loops
        .iter()
        .any(|l| (12..=15).contains(&l.0)));
}

#[test]
fn mriq_reproduces_fig4_shape() {
    let sol = solve("mriq");
    assert!(
        (5.0..10.0).contains(&sol.speedup()),
        "mriq speedup {:.2} out of the paper's ballpark (7.1x)",
        sol.speedup()
    );
    // The winner must include the Q-computation nest (L4/L5).
    assert!(sol
        .best_measurement()
        .loops
        .iter()
        .any(|l| l.0 == 4 || l.0 == 5));
}

#[test]
fn mriq_beats_tdfir_as_in_paper() {
    assert!(solve("mriq").speedup() > solve("tdfir").speedup());
}

#[test]
fn sobel_pipeline_runs_end_to_end() {
    let sol = solve("sobel");
    assert!(!sol.measurements.is_empty());
    // 3x3 stencil with sqrt per pixel: spatialized inner loops should
    // make offloading the gradient nest profitable.
    assert!(sol.speedup() > 1.0, "{:.2}", sol.speedup());
}

#[test]
fn every_measured_pattern_is_numerically_verified() {
    for app in workloads::APPS {
        let sol = solve(app);
        for m in &sol.measurements {
            assert_eq!(
                m.verified,
                Some(true),
                "{app}: pattern {} failed functional verification",
                m.label()
            );
        }
    }
}

#[test]
fn measurement_budget_is_respected_everywhere() {
    let cfg = SearchConfig::default();
    for app in workloads::APPS {
        let sol = solve(app);
        assert!(sol.measurements.len() <= cfg.max_patterns, "{app}");
        // Rounds are 1 or 2 only; round 1 comes first.
        let mut seen_round2 = false;
        for m in &sol.measurements {
            assert!(m.round == 1 || m.round == 2);
            if m.round == 2 {
                seen_round2 = true;
            } else {
                assert!(!seen_round2, "{app}: round 1 after round 2");
            }
        }
    }
}

#[test]
fn solution_json_roundtrips_through_pattern_db() {
    use fpga_offload::envadapt::PatternDb;
    use fpga_offload::util::tempdir::TempDir;
    let dir = TempDir::new("fpga-offload-int-pdb").unwrap();
    let db = PatternDb::open(dir.path()).unwrap();
    let sol = solve("sobel");
    db.store(&sol).unwrap();
    let loaded = db.load("sobel").unwrap().unwrap();
    let speedup = loaded.get(&["speedup"]).unwrap().as_f64().unwrap();
    assert!((speedup - sol.speedup()).abs() < 1e-9);
}
