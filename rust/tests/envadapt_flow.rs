//! Integration: the environment-adaptive-software flow (Fig. 1) with all
//! three layers — including the step-6 PJRT sample test against the real
//! AOT artifacts — plus DB wiring and failure-injection cases.
//!
//! `run_flow` is deprecated in favor of the staged `envadapt::Pipeline`;
//! these tests deliberately keep exercising the shim.

#![allow(deprecated)]

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{
    run_flow, FacilityDb, FlowOptions, TestCase, TestDb,
};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::SearchConfig;
use fpga_offload::workloads;

fn opts_base<'a>() -> FlowOptions<'a> {
    FlowOptions {
        config: SearchConfig::default(),
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
        pattern_db: None,
        runtime: None,
        seed: 42,
    }
}

/// PJRT-backed end-to-end runs. Gated: the offline build ships a stub
/// `xla` crate, so a real client (and `make artifacts` output) exists
/// only when the real binding is wired in via the `pjrt-live` feature.
#[cfg(feature = "pjrt-live")]
mod pjrt_live {
    use super::opts_base;
    use fpga_offload::envadapt::{run_flow, FlowOptions, TestDb};
    use fpga_offload::runtime::{Artifacts, Runtime};
    use fpga_offload::workloads;

    #[test]
    fn full_flow_tdfir_with_pjrt_sample_test() {
        let cwd = std::env::current_dir().unwrap();
        let art = Artifacts::discover(&cwd)
            .expect("artifacts/ missing — run `make artifacts`");
        let rt = Runtime::cpu().unwrap();

        let testdb = TestDb::builtin();
        let opts = FlowOptions {
            runtime: Some((&rt, &art)),
            ..opts_base()
        };
        let report =
            run_flow("tdfir", workloads::TDFIR_C, &testdb, &opts).unwrap();

        // Fig. 4 shape.
        assert!((2.5..7.0).contains(&report.solution.speedup()));
        // Step 6: the Pallas→HLO kernels ran and matched the reference.
        let sr = report.sample_run.expect("PJRT sample test must run");
        assert_eq!(sr.app, "tdfir");
        assert!(sr.max_abs_err < 5e-3);
    }

    #[test]
    fn full_flow_mriq_with_pjrt_sample_test() {
        let cwd = std::env::current_dir().unwrap();
        let art = Artifacts::discover(&cwd).expect("run `make artifacts`");
        let rt = Runtime::cpu().unwrap();
        let testdb = TestDb::builtin();
        let opts = FlowOptions {
            runtime: Some((&rt, &art)),
            ..opts_base()
        };
        let report =
            run_flow("mriq", workloads::MRIQ_C, &testdb, &opts).unwrap();
        assert!((5.0..10.0).contains(&report.solution.speedup()));
        let sr = report.sample_run.unwrap();
        assert_eq!(sr.app, "mriq");
        assert!(sr.max_abs_err < 5e-2);
    }
}

#[test]
fn flow_persists_and_lists_patterns() {
    let dir =
        fpga_offload::util::tempdir::TempDir::new("fpga-offload-flow-int")
            .unwrap();
    let testdb = TestDb::builtin();
    let opts = FlowOptions {
        pattern_db: Some(dir.path()),
        ..opts_base()
    };
    run_flow("sobel", workloads::SOBEL_C, &testdb, &opts).unwrap();
    run_flow("mriq", workloads::MRIQ_C, &testdb, &opts).unwrap();
    let db = fpga_offload::envadapt::PatternDb::open(dir.path()).unwrap();
    assert_eq!(db.list().unwrap(), vec!["mriq", "sobel"]);
}

#[test]
fn facility_db_describes_fig3() {
    let db = FacilityDb::paper_fig3();
    let v = db.verification().unwrap();
    assert_eq!(v.fpga.as_ref().unwrap().name, ARRIA10_GX.name);
    assert_eq!(v.cpu.as_ref().unwrap().name, XEON_BRONZE_3104.name);
    assert_eq!(db.facilities.len(), 3);
}

#[test]
fn flow_fails_cleanly_on_source_with_no_offloadable_loops() {
    let mut testdb = TestDb::new();
    testdb.register(TestCase {
        app: "noloop".into(),
        entry: "main".into(),
        observed_arrays: vec![],
        pjrt_sample: None,
        description: String::new(),
    });
    let src = "int main() { return 42; }";
    let err = run_flow("noloop", src, &testdb, &opts_base()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("no offloadable") || msg.contains("funnel"),
        "{msg}"
    );
}

#[test]
fn flow_rejects_semantic_errors_before_measuring() {
    let mut testdb = TestDb::new();
    testdb.register(TestCase {
        app: "bad".into(),
        entry: "main".into(),
        observed_arrays: vec![],
        pjrt_sample: None,
        description: String::new(),
    });
    let src = "int main() { for (int i = 0; i < 4; i++) { x[i] = 1.0; } return 0; }";
    assert!(run_flow("bad", src, &testdb, &opts_base()).is_err());
}

#[test]
fn custom_search_configs_flow_through() {
    let testdb = TestDb::builtin();
    let opts = FlowOptions {
        config: SearchConfig {
            top_a: 2,
            top_c: 1,
            first_round: 1,
            max_patterns: 2,
            ..Default::default()
        },
        ..opts_base()
    };
    let report =
        run_flow("sobel", workloads::SOBEL_C, &testdb, &opts).unwrap();
    assert!(report.solution.measurements.len() <= 2);
    assert!(report.solution.funnel.top_a.len() <= 2);
    assert!(report.solution.funnel.top_c.len() <= 1);
}
