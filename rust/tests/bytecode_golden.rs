//! Golden-file tests pinning the bytecode encoding of every bundled
//! workload, under both the fused §PGO encoding and the unfused
//! baseline.
//!
//! The disassemblies live in `tests/golden/<app>[.baseline].disasm`.
//! A missing golden is written on first run (bless-on-missing); after
//! an intentional encoding change, re-bless with `UPDATE_GOLDEN=1
//! cargo test --test bytecode_golden`. The structural assertions below
//! hold regardless of blessing, so a fresh checkout still verifies the
//! encoding shape.

use std::fs;
use std::path::PathBuf;

use fpga_offload::minic::{parse, resolve, ResolveOpts};
use fpga_offload::workloads;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join(format!("{name}.disasm"))
}

fn disasm(app: &str, opts: &ResolveOpts) -> String {
    let prog = parse(workloads::source(app).unwrap()).unwrap();
    resolve::compile_with(&prog, opts)
        .unwrap()
        .disassemble()
}

fn check_golden(name: &str, text: &str) {
    let path = golden_path(name);
    if std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, text).unwrap();
        return;
    }
    let want = fs::read_to_string(&path).unwrap();
    assert_eq!(
        want, text,
        "bytecode disassembly for {name} changed — if intentional, \
         re-bless with UPDATE_GOLDEN=1"
    );
}

#[test]
fn bundled_workload_encodings_are_pinned() {
    for app in workloads::APPS {
        check_golden(app, &disasm(app, &ResolveOpts::default()));
        check_golden(
            &format!("{app}.baseline"),
            &disasm(app, &ResolveOpts::baseline()),
        );
    }
}

#[test]
fn fused_encoding_contains_the_profiled_superinstructions() {
    // tdfir's tap loops are the motivating profile: computed-index
    // loads feeding multiplies, local-index loads/stores, counted
    // loops with constant bounds and `i++` steps.
    let t = disasm("tdfir", &ResolveOpts::default());
    for op in [
        "LoadIndexBin",
        "LoadIndexLocal",
        "StoreIndexLocal",
        "CmpConstJump",
        "CompoundLocalConst",
    ] {
        assert!(t.contains(op), "tdfir missing {op}:\n{t}");
    }
    // mriq's phase accumulation is the local-MAC shape.
    let m = disasm("mriq", &ResolveOpts::default());
    assert!(m.contains("MacLocal"), "mriq missing MacLocal:\n{m}");
    // sobel's stencil hits the rank-2 index fusions.
    let s = disasm("sobel", &ResolveOpts::default());
    assert!(s.contains("rank=2"), "sobel missing rank-2 access:\n{s}");
    assert!(s.contains("LoadIndexLocal"), "sobel missing LoadIndexLocal");
}

#[test]
fn baseline_encoding_stays_free_of_pair_fusions() {
    for app in workloads::APPS {
        let d = disasm(app, &ResolveOpts::baseline());
        for op in [
            "LoadIndexLocal",
            "StoreIndexLocal",
            "LoadIndexBin",
            "BinConstInt",
            "CompoundLocalConst",
            "CmpConstJump",
            "BinLocal",
        ] {
            assert!(!d.contains(op), "{app} baseline contains {op}");
        }
        assert!(d.contains("JumpIfFalse"), "{app} baseline lost branches");
    }
    // MacLocal predates the §PGO pass and fires under every encoding.
    let m = disasm("mriq", &ResolveOpts::baseline());
    assert!(m.contains("MacLocal"), "mriq baseline lost MacLocal");
}
