#!/usr/bin/env python3
"""Offline replica of the rust_pallas cost model.

Used to design/re-tune the bundled workload .c files (rust/src/workloads/c/)
so the integration-test speedup windows hold: it mirrors the MiniC
interpreter op counting, loop analysis, HLS estimate/schedule, FPGA
simulate, and the narrowing funnel + two measurement rounds.

Usage: python3 tools/costmodel_check.py rust/src/workloads/c/tdfir.c
"""
import math, re, sys
sys.setrecursionlimit(100000)

# ------------------------- lexer -------------------------
TOK_RE = re.compile(r"""
  (?P<ws>\s+|//[^\n]*|(?s:/\*.*?\*/)|\#include[^\n]*)
| (?P<define>\#define)
| (?P<float>\d+\.\d*(e[+-]?\d+)?|\.\d+|\d+e[+-]?\d+)
| (?P<int>\d+)
| (?P<id>[A-Za-z_]\w*)
| (?P<str>"(\\.|[^"\\])*")
| (?P<op>\+\+|--|\+=|-=|\*=|/=|==|!=|<=|>=|&&|\|\||[-+*/%<>=!(){}\[\];,])
""", re.VERBOSE)

KEYWORDS = {"int","float","double","void","const","if","else","for","while","return"}

def lex(src):
    toks, i = [], 0
    while i < len(src):
        m = TOK_RE.match(src, i)
        if not m:
            raise SyntaxError(f"lex error at {src[i:i+20]!r}")
        i = m.end()
        if m.lastgroup == "ws":
            continue
        kind, val = m.lastgroup, m.group()
        if kind == "id" and val in KEYWORDS:
            kind = val
        elif kind == "int":
            kind = "ilit"
        elif kind == "float":
            kind = "flit"
        toks.append((kind, val))
    toks.append(("eof",""))
    return toks

# ------------------------- AST + parser -------------------------
class P:
    def __init__(self, src):
        self.t = lex(src); self.i = 0
        self.defines = []      # (name, float)
        self.globals = []      # Decl stmts
        self.funcs = {}        # name -> (params, body)  params: (name, ty)
        self.funcorder = []
        self.next_loop = 0
    def peek(self, k=0): return self.t[self.i+k]
    def bump(self):
        tok = self.t[self.i]; self.i += 1; return tok
    def accept(self, kind, val=None):
        k,v = self.peek()
        if k==kind and (val is None or v==val):
            self.bump(); return True
        return False
    def expect(self, kind, val=None):
        if not self.accept(kind,val):
            raise SyntaxError(f"expected {kind} {val} got {self.peek()} @{self.i}")
    def parse(self):
        while self.peek()[0] != "eof":
            if self.accept("define"):
                name = self.bump()[1]
                neg = self.accept("op","-")
                k,v = self.bump()
                x = float(v)
                self.defines.append((name, -x if neg else x))
            else:
                self.top_item()
        return self
    def scalar_type(self):
        self.accept("const")
        k,v = self.bump()
        assert k in ("int","float","double","void"), (k,v)
        return v
    def starts_type(self):
        return self.peek()[0] in ("int","float","double","void","const")
    def top_item(self):
        sc = self.scalar_type()
        is_ptr = self.accept("op","*")
        name = self.bump()[1]
        if self.peek() == ("op","("):
            params = self.params()
            body = self.block()
            self.funcs[name] = (params, body)
            self.funcorder.append(name)
        else:
            d = self.decl_rest(sc, is_ptr, name)
            self.expect("op",";")
            self.globals.append(d)
    def params(self):
        self.expect("op","(")
        ps = []
        if self.accept("op",")"): return ps
        while True:
            if self.peek()[0]=="void" and self.peek(1)==("op",")"):
                self.bump(); break
            sc = self.scalar_type()
            is_ptr = self.accept("op","*")
            pname = self.bump()[1]
            dims = self.dims() if self.peek()==("op","[") else None
            ty = ("ptr",sc) if is_ptr else (("arr",sc,dims) if dims else ("scalar",sc))
            ps.append((pname,ty))
            if not self.accept("op",","): break
        self.expect("op",")")
        return ps
    def dims(self):
        ds=[]
        while self.accept("op","["):
            ds.append(self.const_dim())
            self.expect("op","]")
        return ds
    def const_dim(self):
        acc = self.const_atom()
        while True:
            if self.accept("op","*"): acc *= self.const_atom()
            elif self.accept("op","+"): acc += self.const_atom()
            elif self.accept("op","-"): acc -= self.const_atom()
            else: return acc
    def const_atom(self):
        k,v = self.bump()
        if k=="ilit": return int(v)
        if k=="id":
            for n,x in reversed(self.defines):
                if n==v: return int(x)
            raise SyntaxError(f"dim {v} not a define")
        raise SyntaxError(f"bad dim atom {k} {v}")
    def decl_rest(self, sc, is_ptr, name):
        if is_ptr: ty = ("ptr",sc)
        elif self.peek()==("op","["): ty = ("arr",sc,self.dims())
        else: ty = ("scalar",sc)
        init = None
        if self.accept("op","="):
            init = self.expr()
        return ("decl", name, ty, init)
    def block(self):
        self.expect("op","{")
        out=[]
        while not self.accept("op","}"):
            out.append(self.stmt())
        return out
    def body(self):
        if self.peek()==("op","{"): return self.block()
        return [self.stmt()]
    def stmt(self):
        k,v = self.peek()
        if k=="if": return self.if_stmt()
        if k=="for": return self.for_stmt()
        if k=="while": return self.while_stmt()
        if k=="return":
            self.bump()
            val = None if self.peek()==("op",";") else self.expr()
            self.expect("op",";")
            return ("return", val)
        if self.starts_type():
            sc = self.scalar_type()
            is_ptr = self.accept("op","*")
            name = self.bump()[1]
            d = self.decl_rest(sc,is_ptr,name)
            self.expect("op",";")
            return d
        s = self.simple_stmt()
        self.expect("op",";")
        return s
    def simple_stmt(self):
        name = self.bump()[1]
        if self.peek()==("op","("):
            args = self.call_args()
            return ("exprstmt", ("call",name,args))
        if self.peek()==("op","["):
            idx=[]
            while self.accept("op","["):
                idx.append(self.expr()); self.expect("op","]")
            target=("index",name,idx)
        else:
            target=("var",name)
        k,v = self.peek()
        ops = {"=":"set","+=":"add","-=":"sub","*=":"mul","/=":"div"}
        if v in ops:
            self.bump(); return ("assign",target,ops[v],self.expr())
        if v=="++": self.bump(); return ("assign",target,"add",("int",1))
        if v=="--": self.bump(); return ("assign",target,"sub",("int",1))
        raise SyntaxError(f"expected assignment at {self.peek()}")
    def if_stmt(self):
        self.expect("if"); self.expect("op","(")
        c=self.expr(); self.expect("op",")")
        th=self.body()
        el=[]
        if self.accept("else"):
            el=[self.if_stmt()] if self.peek()[0]=="if" else self.body()
        return ("if",c,th,el)
    def for_stmt(self):
        lid = self.next_loop; self.next_loop += 1
        self.expect("for"); self.expect("op","(")
        if self.peek()==("op",";"): init=None
        elif self.starts_type():
            sc=self.scalar_type(); name=self.bump()[1]
            init=self.decl_rest(sc,False,name)
        else: init=self.simple_stmt()
        self.expect("op",";")
        cond=None if self.peek()==("op",";") else self.expr()
        self.expect("op",";")
        step=None if self.peek()==("op",")") else self.simple_stmt()
        self.expect("op",")")
        body=self.body()
        return ("for",lid,init,cond,step,body)
    def while_stmt(self):
        lid=self.next_loop; self.next_loop+=1
        self.expect("while"); self.expect("op","(")
        c=self.expr(); self.expect("op",")")
        return ("while",lid,c,self.body())
    # exprs
    def expr(self): return self.or_()
    def or_(self):
        l=self.and_()
        while self.accept("op","||"): l=("bin","or",l,self.and_())
        return l
    def and_(self):
        l=self.eq()
        while self.accept("op","&&"): l=("bin","and",l,self.eq())
        return l
    def eq(self):
        l=self.rel()
        while self.peek()[1] in ("==","!=") and self.peek()[0]=="op":
            op=self.bump()[1]; l=("bin","eq" if op=="==" else "ne",l,self.rel())
        return l
    def rel(self):
        l=self.add()
        while self.peek()[0]=="op" and self.peek()[1] in ("<",">","<=",">="):
            op=self.bump()[1]
            m={"<":"lt",">":"gt","<=":"le",">=":"ge"}
            l=("bin",m[op],l,self.add())
        return l
    def add(self):
        l=self.mul()
        while self.peek()[0]=="op" and self.peek()[1] in ("+","-"):
            op=self.bump()[1]; l=("bin","add" if op=="+" else "sub",l,self.mul())
        return l
    def mul(self):
        l=self.unary()
        while self.peek()[0]=="op" and self.peek()[1] in ("*","/","%"):
            op=self.bump()[1]
            m={"*":"mul","/":"div","%":"rem"}
            l=("bin",m[op],l,self.unary())
        return l
    def unary(self):
        if self.peek()==("op","-"):
            self.bump(); return ("neg",self.unary())
        if self.peek()==("op","!"):
            self.bump(); return ("not",self.unary())
        if self.peek()==("op","(") and self.peek(1)[0] in ("int","float","double"):
            self.bump(); sc=self.scalar_type(); self.expect("op",")")
            return ("cast",sc,self.unary())
        return self.postfix()
    def postfix(self):
        k,v=self.peek()
        if k=="ilit": self.bump(); return ("int",int(v))
        if k=="flit": self.bump(); return ("flt",float(v))
        if k=="str": self.bump(); return ("strlit",v)
        if v=="(" and k=="op":
            self.bump(); e=self.expr(); self.expect("op",")"); return e
        if k=="id":
            self.bump()
            if self.peek()==("op","("):
                return ("call",v,self.call_args())
            if self.peek()==("op","["):
                idx=[]
                while self.accept("op","["):
                    idx.append(self.expr()); self.expect("op","]")
                return ("index",v,idx)
            return ("var",v)
        raise SyntaxError(f"expected expression at {self.peek()}")
    def call_args(self):
        self.expect("op","(")
        args=[]
        if self.accept("op",")"): return args
        while True:
            args.append(self.expr())
            if not self.accept("op",","): break
        self.expect("op",")")
        return args

# ------------------------- interpreter with OpCounts -------------------------
BUILTIN1 = {"sin":math.sin,"cos":math.cos,"tan":math.tan,"sqrt":math.sqrt,
            "sqrtf":math.sqrt,"exp":math.exp,"log":math.log,"fabs":abs,
            "floor":math.floor,"ceil":math.ceil}

FIELDS = ("f_add","f_mul","f_div","f_trig","i_op","cmp","reads","writes","read_bytes","write_bytes")
class Ops:
    __slots__ = FIELDS
    def __init__(self):
        for f in FIELDS: setattr(self,f,0)
    def snap(self): return tuple(getattr(self,f) for f in FIELDS)
    def delta(self, s): return {f: getattr(self,f)-s[i] for i,f in enumerate(FIELDS)}
    def asdict(self): return {f:getattr(self,f) for f in FIELDS}

def size_of(sc): return 8 if sc=="double" else 4 if sc in ("int","float") else 0

class Ret(Exception):
    def __init__(self,v): self.v=v

class Interp:
    def __init__(self, prog):
        self.p = prog
        self.arena = []   # (elem, dims, data list)
        self.globals = {}
        self.total = Ops()
        self.slots = [ {"entries":0,"trips":0,"snapbase":None,"ops":{f:0 for f in FIELDS},
                        "ar":set(),"aw":set()} for _ in range(prog.next_loop)]
        self.stack = []   # [(lid, snapshot)]
        for n,v in prog.defines:
            self.globals[n] = int(v) if v==int(v) else v
        for (_,name,ty,init) in prog.globals:
            if ty[0]=="arr":
                elem,dims = ty[1],ty[2]
                n = 1
                for d in dims: n*=d
                self.arena.append((elem,dims,[0.0]*n))
                self.globals[name] = ("ARR",len(self.arena)-1)
            else:
                self.globals[name] = 0 if ty[1]=="int" else 0.0
            if init is not None:
                self.globals[name] = self.eval(init, [{}])
    def call(self, name, args=()):
        params, body = self.p.funcs[name]
        env=[{}]
        for (pn,ty),a in zip(params,args):
            env[0][pn]=a
        try:
            self.exec_block(body, env)
        except Ret as r:
            return r.v
        return 0
    def exec_block(self, stmts, env):
        needs = any(s[0]=="decl" for s in stmts)
        if needs: env.append({})
        try:
            for s in stmts:
                self.exec(s, env)
        finally:
            if needs: env.pop()
    def lookup(self, name, env):
        for sc in reversed(env):
            if name in sc: return sc[name]
        return self.globals.get(name)
    def set_var(self, name, v, env):
        for sc in reversed(env):
            if name in sc:
                sc[name]=v; return
        if name in self.globals:
            self.globals[name]=v; return
        raise RuntimeError(f"undeclared {name}")
    def exec(self, s, env):
        t=self.total
        k=s[0]
        if k=="decl":
            _,name,ty,init = s
            if ty[0]=="arr":
                elem,dims=ty[1],ty[2]
                n=1
                for d in dims:n*=d
                self.arena.append((elem,dims,[0.0]*n))
                env[-1][name]=("ARR",len(self.arena)-1)
            else:
                env[-1][name]= 0 if ty[1]=="int" else 0.0
            if init is not None:
                v=self.eval(init,env)
                if ty[0]=="scalar":
                    if ty[1]=="int" and isinstance(v,float): v=int(v)
                    elif ty[1] in ("float","double") and isinstance(v,int): v=float(v)
                self.set_var(name,v,env)
        elif k=="assign":
            _,target,op,value = s
            rhs=self.eval(value,env)
            if target[0]=="var":
                name=target[1]
                if op=="set": new=rhs
                else:
                    old=self.lookup(name,env)
                    new=self.apply_bin(op,old,rhs)
                self.set_var(name,new,env)
            else:
                _,base,indices=target
                idx=[self.as_int(self.eval(e,env)) for e in indices]
                t.i_op+=len(idx)
                arr=self.lookup(base,env)
                elem,dims,data=self.arena[arr[1]]
                flat=self.flat(idx,dims)
                esz=size_of(elem)
                if op=="set": new=rhs
                else:
                    old=data[flat]  # always float
                    self.count_read(base,esz)
                    new=self.apply_bin(op,old,rhs)
                data[flat]=float(new)
                self.count_write(base,esz)
        elif k=="if":
            _,c,th,el=s
            v=self.eval(c,env)
            t.cmp+=1
            self.exec_block(th if v!=0 else el, env)
        elif k=="for":
            _,lid,init,cond,step,body=s
            env.append({})
            try:
                if init is not None: self.exec(init,env)
                snap=self.total.snap()
                self.stack.append(lid)
                self.slots[lid]["entries"]+=1
                try:
                    while True:
                        if cond is not None:
                            t.cmp+=1
                            if self.eval(cond,env)==0: break
                        self.slots[lid]["trips"]+=1
                        self.exec_block(body,env)
                        if step is not None: self.exec(step,env)
                finally:
                    self.stack.pop()
                    d=self.total.delta(snap)
                    for f in FIELDS: self.slots[lid]["ops"][f]+=d[f]
            finally:
                env.pop()
        elif k=="while":
            _,lid,cond,body=s
            snap=self.total.snap()
            self.stack.append(lid)
            self.slots[lid]["entries"]+=1
            try:
                while True:
                    t.cmp+=1
                    if self.eval(cond,env)==0: break
                    self.slots[lid]["trips"]+=1
                    self.exec_block(body,env)
            finally:
                self.stack.pop()
                d=self.total.delta(snap)
                for f in FIELDS: self.slots[lid]["ops"][f]+=d[f]
        elif k=="return":
            raise Ret(0 if s[1] is None else self.eval(s[1],env))
        elif k=="exprstmt":
            self.eval(s[1],env)
        else:
            raise RuntimeError(k)
    def count_read(self,base,esz):
        t=self.total
        t.reads+=1; t.read_bytes+=esz
        for lid in self.stack: self.slots[lid]["ar"].add(base)
    def count_write(self,base,esz):
        t=self.total
        t.writes+=1; t.write_bytes+=esz
        for lid in self.stack: self.slots[lid]["aw"].add(base)
    def flat(self,idx,dims):
        assert len(idx)==len(dims), (idx,dims)
        f=0
        for i,d in zip(idx,dims):
            assert 0<=i<d, (idx,dims)
            f=f*d+i
        return f
    def as_int(self,v): return v if isinstance(v,int) else int(v)
    def apply_bin(self,op,l,r):
        t=self.total
        if isinstance(l,int) and isinstance(r,int):
            if op in ("add","sub","mul","div","rem"):
                t.i_op+=1
                if op=="add": return l+r
                if op=="sub": return l-r
                if op=="mul": return l*r
                if op=="div": return int(l/r) if r!=0 else 1/0
                if op=="rem": return l-int(l/r)*r
            t.cmp+=1
            return int(CMP[op](l,r))
        a=float(l); b=float(r)
        if op in ("add","sub"): t.f_add+=1; return a+b if op=="add" else a-b
        if op=="mul": t.f_mul+=1; return a*b
        if op=="div": t.f_div+=1; return a/b
        if op=="rem": t.f_div+=1; return math.fmod(a,b)
        t.cmp+=1
        return int(CMP[op](a,b))
    def eval(self,e,env):
        t=self.total
        k=e[0]
        if k=="int" or k=="flt": return e[1]
        if k=="strlit": return 0
        if k=="var":
            v=self.lookup(e[1],env)
            if v is None: raise RuntimeError(f"undeclared {e[1]}")
            return v
        if k=="index":
            _,base,indices=e
            idx=[self.as_int(self.eval(x,env)) for x in indices]
            t.i_op+=len(idx)
            arr=self.lookup(base,env)
            elem,dims,data=self.arena[arr[1]]
            v=data[self.flat(idx,dims)]
            self.count_read(base,size_of(elem))
            return int(v) if elem=="int" else v
        if k=="bin":
            _,op,l,r=e
            if op=="and":
                lv=self.eval(l,env); t.cmp+=1
                if lv==0: return 0
                return int(self.eval(r,env)!=0)
            if op=="or":
                lv=self.eval(l,env); t.cmp+=1
                if lv!=0: return 1
                return int(self.eval(r,env)!=0)
            lv=self.eval(l,env); rv=self.eval(r,env)
            return self.apply_bin(op,lv,rv)
        if k=="neg":
            v=self.eval(e[1],env)
            if isinstance(v,int): t.i_op+=1; return -v
            t.f_add+=1; return -v
        if k=="not":
            v=self.eval(e[1],env); t.cmp+=1; return int(v==0)
        if k=="cast":
            v=self.eval(e[2],env)
            return int(v) if e[1]=="int" else float(v)
        if k=="call":
            _,name,args=e
            if name in BUILTIN1:
                v=float(self.eval(args[0],env)); t.f_trig+=1
                return BUILTIN1[name](v)
            if name=="printf":
                for a in args[1:]: self.eval(a,env)
                return 0
            if name in ("fmin","fmax"):
                a=float(self.eval(args[0],env)); b=float(self.eval(args[1],env))
                t.cmp+=1
                return min(a,b) if name=="fmin" else max(a,b)
            if name=="pow":
                a=float(self.eval(args[0],env)); b=float(self.eval(args[1],env))
                t.f_trig+=1
                return a**b
            vals=[self.eval(a,env) for a in args]
            return self.call(name,vals)
        raise RuntimeError(k)

CMP={"eq":lambda a,b:a==b,"ne":lambda a,b:a!=b,"lt":lambda a,b:a<b,
     "gt":lambda a,b:a>b,"le":lambda a,b:a<=b,"ge":lambda a,b:a>=b}

# ------------------------- static analysis -------------------------
def walk_stmt(s, f):
    f(s)
    k=s[0]
    if k=="if":
        for x in s[2]+s[3]: walk_stmt(x,f)
    elif k=="for":
        if s[2] is not None: walk_stmt(s[2],f)
        if s[4] is not None: walk_stmt(s[4],f)
        for x in s[5]: walk_stmt(x,f)
    elif k=="while":
        for x in s[3]: walk_stmt(x,f)

def walk_expr(e, f):
    f(e)
    k=e[0]
    if k=="index":
        for x in e[2]: walk_expr(x,f)
    elif k=="bin":
        walk_expr(e[2],f); walk_expr(e[3],f)
    elif k in ("neg","not"):
        walk_expr(e[1],f)
    elif k=="cast":
        walk_expr(e[2],f)
    elif k=="call":
        for x in e[2]: walk_expr(x,f)

def loop_table(prog):
    """[{id, func, depth, parent, children, induction, static_trips,
        arrays_read, arrays_written, free_scalars, blocker}]"""
    out={}
    defmap=dict(prog.defines)
    def is_array(name, params):
        for (_,gn,ty,_) in prog.globals:
            if gn==name and ty[0] in ("arr","ptr"): return True
        for pn,ty in params:
            if pn==name and ty[0] in ("arr","ptr"): return True
        return False
    def const_eval(e):
        k=e[0]
        if k=="int": return float(e[1])
        if k=="flt": return e[1]
        if k=="var": return defmap.get(e[1])
        if k=="bin":
            a=const_eval(e[2]); b=const_eval(e[3])
            if a is None or b is None: return None
            if e[1]=="add": return a+b
            if e[1]=="sub": return a-b
            if e[1]=="mul": return a*b
            if e[1]=="div": return a/b if b!=0 else None
            return None
        if k=="neg":
            v=const_eval(e[1]); return -v if v is not None else None
        if k=="cast": return const_eval(e[2])
        return None
    def static_trips(init,cond,step):
        def ivar(st):
            if st is None: return None
            if st[0]=="decl": return st[1]
            if st[0]=="assign" and st[1][0]=="var": return st[1][1]
            return None
        v1=ivar(init);
        v2=ivar(step)
        if v1 is None or v1!=v2: return None
        if init[0]=="decl":
            if init[3] is None: return None
            start=const_eval(init[3])
        else: start=const_eval(init[3])
        if start is None: return None
        if step[0]!="assign": return None
        if step[2]=="add": stride=const_eval(step[3])
        elif step[2]=="set" and step[3][0]=="bin" and step[3][1]=="add" and step[3][2]==("var",v1):
            stride=const_eval(step[3][3])
        else: return None
        if stride is None or stride<=0: return None
        if cond is None or cond[0]!="bin": return None
        if cond[2]!=("var",v1): return None
        if cond[1]=="lt": bound=const_eval(cond[3]); inc=0.0
        elif cond[1]=="le": bound=const_eval(cond[3]); inc=1.0
        else: return None
        if bound is None: return None
        span=bound-start+inc
        if span<=0: return 0
        return math.ceil(span/stride)
    def analyze_loop(s, fname, params, depth, parent):
        lid=s[1]
        declared=set()
        if s[0]=="for" and s[2] is not None and s[2][0]=="decl":
            declared.add(s[2][1])
        body = s[5] if s[0]=="for" else s[3]
        for st in body:
            def cd(x):
                if x[0]=="decl": declared.add(x[1])
                if x[0]=="for" and x[2] is not None and x[2][0]=="decl":
                    declared.add(x[2][1])
            walk_stmt(st,cd)
        info={"id":lid,"func":fname,"depth":depth,"parent":parent,"children":[],
              "induction":None,"static_trips":None,"ar":set(),"aw":set(),
              "free":set(),"blocker":None}
        if s[0]=="while":
            info["blocker"]="while"
        else:
            init,cond,step=s[2],s[3],s[4]
            def ivar(st):
                if st is None: return None
                if st[0]=="decl": return st[1]
                if st[0]=="assign" and st[1][0]=="var": return st[1][1]
                return None
            if ivar(init) is not None and ivar(init)==ivar(step):
                info["induction"]=ivar(init)
            info["static_trips"]=static_trips(init,cond,step)
        def note_expr(e):
            def g(x):
                if x[0]=="index": info["ar"].add(x[1])
                elif x[0]=="var":
                    n=x[1]
                    if n not in declared and not is_array(n,params) and n not in defmap:
                        info["free"].add(n)
                elif x[0]=="call":
                    n=x[1]
                    if n=="printf":
                        info["blocker"]=info["blocker"] or "io"
                    elif n not in BUILTIN1 and n not in ("fmin","fmax","pow") and n in prog.funcs:
                        info["blocker"]=info["blocker"] or "usercall"
            walk_expr(e,g)
        if s[0]=="for":
            if s[3] is not None: note_expr(s[3])
            if s[4] is not None and s[4][0]=="assign": note_expr(s[4][3])
        else:
            note_expr(s[2])
        for st in body:
            def h(x):
                k=x[0]
                if k=="assign":
                    tgt=x[1]
                    if tgt[0]=="index":
                        info["aw"].add(tgt[1])
                        for i in tgt[2]: note_expr(i)
                    else:
                        if tgt[1] not in declared:
                            info["free"].add(tgt[1])
                    note_expr(x[3])
                elif k=="decl":
                    if x[3] is not None: note_expr(x[3])
                elif k=="if": note_expr(x[1])
                elif k=="for":
                    if x[3] is not None: note_expr(x[3])
                    if x[4] is not None and x[4][0]=="assign": note_expr(x[4][3])
                elif k=="while": note_expr(x[2])
                elif k=="return":
                    info["blocker"]=info["blocker"] or "return"
                elif k=="exprstmt": note_expr(x[1])
            walk_stmt(st,h)
        out[lid]=info
        if parent is not None:
            out[parent]["children"].append(lid)
        for st in body:
            def rec(x, d):
                if x[0] in ("for","while"):
                    analyze_loop(x,fname,params,d,lid)
                    return True
                return False
            walk_top(st, lambda x: analyze_loop(x,fname,params,depth+1,lid))
        # propagate child blockers
        for c in out[lid]["children"]:
            if out[c]["blocker"] is not None and out[lid]["blocker"] is None:
                out[lid]["blocker"]="nested"
        return info
    def walk_top(s, on_loop):
        """call on_loop for direct loop statements (not entering them)"""
        k=s[0]
        if k in ("for","while"):
            on_loop(s)
        elif k=="if":
            for x in s[2]+s[3]: walk_top(x,on_loop)
    for fname in prog.funcorder:
        params,body=prog.funcs[fname]
        for s in body:
            def walk_ifs(x):
                if x[0] in ("for","while"):
                    analyze_loop(x,fname,params,0,None)
                elif x[0]=="if":
                    for y in x[2]+x[3]: walk_ifs(y)
            walk_ifs(s)
    # fix blocker propagation bottom-up (repeat to fixpoint)
    changed=True
    while changed:
        changed=False
        for lid,info in out.items():
            for c in info["children"]:
                if out[c]["blocker"] is not None and info["blocker"] is None:
                    info["blocker"]="nested"; changed=True
    return out

# ------------------------- depend classify -------------------------
def classify(loop_stmt):
    body = loop_stmt[5] if loop_stmt[0]=="for" else loop_stmt[3]
    induction=None
    if loop_stmt[0]=="for":
        init,step=loop_stmt[2],loop_stmt[4]
        def ivar(st):
            if st is None: return None
            if st[0]=="decl": return st[1]
            if st[0]=="assign" and st[1][0]=="var": return st[1][1]
            return None
        if ivar(init) is not None and ivar(init)==ivar(step):
            induction=ivar(init)
    local=set()
    for st in body:
        def cd(x):
            if x[0]=="decl": local.add(x[1])
            if x[0]=="for" and x[2] is not None:
                if x[2][0]=="decl": local.add(x[2][1])
                elif x[2][0]=="assign" and x[2][1][0]=="var": local.add(x[2][1][1])
        walk_stmt(st,cd)
    events=[]
    def emit_expr(e):
        def g(x):
            if x[0]=="var": events.append(("rs",x[1]))
            elif x[0]=="index": events.append(("ra",x[1],repr(x[2])))
        walk_expr(e,g)
    def self_update_rest(name, value):
        # value == name op rest?
        if value[0]=="bin" and value[1] in ("add","sub","mul","div"):
            if value[2]==("var",name): return value[3]
        return None
    def emit_stmt(s):
        k=s[0]
        if k=="decl":
            if s[3] is not None: emit_expr(s[3])
        elif k=="assign":
            _,tgt,op,value=s
            if tgt[0]=="var":
                name=tgt[1]
                if op!="set":
                    emit_expr(value); red=True
                else:
                    rest=self_update_rest(name,value)
                    if rest is not None:
                        emit_expr(rest); red=True
                    else:
                        emit_expr(value); red=False
                events.append(("ws",name,red))
            else:
                emit_expr(value)
                for i in tgt[2]: emit_expr(i)
                if op!="set": events.append(("ra",tgt[1],repr(tgt[2])))
                events.append(("wa",tgt[1],repr(tgt[2])))
        elif k=="if":
            emit_expr(s[1])
            for x in s[2]+s[3]: emit_stmt(x)
        elif k=="for":
            if s[2] is not None: emit_stmt(s[2])
            if s[3] is not None: emit_expr(s[3])
            for x in s[5]: emit_stmt(x)
            if s[4] is not None: emit_stmt(s[4])
        elif k=="while":
            emit_expr(s[2])
            for x in s[3]: emit_stmt(x)
        elif k=="return":
            if s[1] is not None: emit_expr(s[1])
        elif k=="exprstmt":
            emit_expr(s[1])
    for s in body: emit_stmt(s)
    aw={}
    for e in events:
        if e[0]=="wa": aw.setdefault(e[1],[]).append(e[2])
    for e in events:
        if e[0]=="ra" and e[1] in aw:
            if any(w!=e[2] for w in aw[e[1]]):
                return ("carried",e[1])
    tracked=lambda n: n not in local and n!=induction
    state={}
    for e in events:
        if e[0]=="rs" and tracked(e[1]):
            st=state.setdefault(e[1],{"rf":False,"w":False,"pw":False,"rw":False,"raw":False})
            if st["w"]: st["raw"]=True
            else: st["rf"]=True
        elif e[0]=="ws" and tracked(e[1]):
            st=state.setdefault(e[1],{"rf":False,"w":False,"pw":False,"rw":False,"raw":False})
            st["w"]=True
            if e[2]: st["rw"]=True
            else: st["pw"]=True
    reds=set()
    for n,st in sorted(state.items()):
        if not st["w"]: continue
        if st["rw"] and not st["pw"] and not st["rf"] and not st["raw"]:
            reds.add(n); continue
        if st["rw"]: return ("carried",n)
        if st["rf"]: return ("carried",n)
    return ("reduction",reds) if reds else ("independent",)

# ------------------------- hls inventory/estimate/schedule -------------------------
SPATIAL_MAX=64
INV_FIELDS=("f_add","f_mul","f_div","f_trig","i_op","cmp","loads","stores","inner_loops","ports")
def inv_new(): return {f:0 for f in INV_FIELDS}
def inv_add(a,b):
    for f in INV_FIELDS: a[f]+=b[f]
def inv_scale(a,t):
    out=dict(a)
    for f in INV_FIELDS:
        if f not in ("inner_loops","ports"): out[f]=a[f]*t
    return out

def local_static_trips(s, defines):
    if s[0]!="for": return None
    defmap={}
    for n,v in defines: defmap[n]=v
    def ev(e):
        k=e[0]
        if k=="int": return float(e[1])
        if k=="flt": return e[1]
        if k=="var": return defmap.get(e[1])
        if k=="bin":
            a=ev(e[2]); b=ev(e[3])
            if a is None or b is None: return None
            return {"add":a+b,"sub":a-b,"mul":a*b,"div":a/b if b!=0 else None}.get(e[1])
        if k=="neg":
            v=ev(e[1]); return -v if v is not None else None
        return None
    init,cond,step=s[2],s[3],s[4]
    if init is None or step is None or cond is None: return None
    if init[0]=="decl":
        var=init[1]; start=ev(init[3]) if init[3] is not None else None
    elif init[0]=="assign" and init[1][0]=="var":
        var=init[1][1]; start=ev(init[3])
    else: return None
    if start is None: return None
    if step[0]=="assign" and step[2]=="add": stride=ev(step[3])
    else: return None
    if stride is None or stride<=0: return None
    if cond[0]!="bin" or cond[2]!=("var",var): return None
    if cond[1]=="lt": bound=ev(cond[3]); inc=0.0
    elif cond[1]=="le": bound=ev(cond[3]); inc=1.0
    else: return None
    if bound is None: return None
    span=bound-start+inc
    if span<=0: return 0
    return math.ceil(span/stride)

def has_nested_loop(stmts):
    found=[False]
    for s in stmts:
        def g(x):
            if x[0] in ("for","while"): found[0]=True
        walk_stmt(s,g)
    return found[0]

def expr_ops(e, inv, addr=False):
    k=e[0]
    if k=="bin":
        op=e[1]
        if addr: inv["i_op"]+=1
        elif op in ("add","sub"): inv["f_add"]+=1
        elif op=="mul": inv["f_mul"]+=1
        elif op in ("div","rem"): inv["f_div"]+=1
        else: inv["cmp"]+=1
        expr_ops(e[2],inv,addr); expr_ops(e[3],inv,addr)
    elif k=="neg":
        if addr: inv["i_op"]+=1
        else: inv["f_add"]+=1
        expr_ops(e[1],inv,addr)
    elif k=="not":
        if addr: inv["i_op"]+=1
        else: inv["cmp"]+=1
        expr_ops(e[1],inv,addr)
    elif k=="index":
        inv["loads"]+=1
        inv["i_op"]+=len(e[2])
        for i in e[2]: expr_ops(i,inv,True)
    elif k=="call":
        if e[1]!="printf": inv["f_trig"]+=1
        for a in e[2]: expr_ops(a,inv,addr)
    elif k=="cast":
        expr_ops(e[2],inv,addr)

def stmt_ops(s, defines):
    inv=inv_new()
    k=s[0]
    if k=="decl":
        if s[3] is not None: expr_ops(s[3],inv)
    elif k=="assign":
        _,tgt,op,value=s
        expr_ops(value,inv)
        if tgt[0]=="index":
            for i in tgt[2]: expr_ops(i,inv,True)
            inv["i_op"]+=len(tgt[2])
            inv["stores"]+=1
            if op!="set":
                inv["loads"]+=1
                inv["f_add"]+=1
        else:
            if op!="set": inv["f_add"]+=1
    elif k=="if":
        expr_ops(s[1],inv)
        for x in s[2]+s[3]: inv_add(inv,stmt_ops(x,defines))
    elif k=="for":
        body=s[5]
        binv=inv_new()
        nested=has_nested_loop(body)
        for x in body: inv_add(binv,stmt_ops(x,defines))
        t=local_static_trips(s,defines)
        if t is not None and not nested and t<=SPATIAL_MAX:
            inv_add(inv,inv_scale(binv,int(t)))
        else:
            inv["inner_loops"]+=1; inv["cmp"]+=1; inv["i_op"]+=1
            if s[3] is not None: expr_ops(s[3],inv)
            inv_add(inv,binv)
    elif k=="while":
        inv["inner_loops"]+=1
        expr_ops(s[2],inv)
        for x in s[3]: inv_add(inv,stmt_ops(x,defines))
    elif k=="return":
        if s[1] is not None: expr_ops(s[1],inv)
    elif k=="exprstmt":
        expr_ops(s[1],inv)
    return inv

def inventory(loop_stmt, defines):
    inv=inv_new()
    body = loop_stmt[5] if loop_stmt[0]=="for" else loop_stmt[3]
    inv["cmp"]+=1; inv["i_op"]+=1
    for s in body: inv_add(inv,stmt_ops(s,defines))
    return inv

def spatial_factor(loop_stmt, defines):
    best=[1]
    body = loop_stmt[5] if loop_stmt[0]=="for" else loop_stmt[3]
    for s in body:
        def g(x):
            if x[0]=="for":
                if not has_nested_loop(x[5]):
                    t=local_static_trips(x,defines)
                    if t is not None and t<=SPATIAL_MAX:
                        best[0]=max(best[0],int(t))
        walk_stmt(s,g)
    return best[0]

DEV=dict(luts=854400,ffs=1708800,dsps=1518,bram_bits=55562240,bsp=0.18,
         clock=240e6,pcie=6e9,dma_lat=12e-6,launch=6e-6)
def usable(x): return int(x*(1-DEV["bsp"]))
LOCAL_CACHE_MAX=256*1024
M20K=20480

def estimate(loop_stmt, arrays, defines):
    """arrays: list of (name, elem, dims, direction) kernel array params"""
    inv=inventory(loop_stmt, defines)
    lut=2400 + inv["f_add"]*110+inv["f_mul"]*100+inv["f_div"]*3000+inv["f_trig"]*5800+inv["i_op"]*64+inv["cmp"]*36
    ff=3600 + inv["f_add"]*170+inv["f_mul"]*160+inv["f_div"]*3600+inv["f_trig"]*7200+inv["i_op"]*64+inv["cmp"]*18
    dsp=inv["f_add"]+inv["f_mul"]+inv["f_trig"]*8
    lut+=len(arrays)*1600; ff+=len(arrays)*2600
    lut+=(inv["loads"]+inv["stores"])*210; ff+=(inv["loads"]+inv["stores"])*260
    lut+=(1+inv["inner_loops"])*320; ff+=(1+inv["inner_loops"])*420
    bram=0
    for (name,elem,dims,_) in arrays:
        nb=size_of(elem)
        for d in dims: nb*=d
        if nb<=LOCAL_CACHE_MAX:
            bits=max(nb*8,M20K)
            bram+=math.ceil(bits/M20K)*M20K
    return dict(luts=lut,ffs=ff,dsps=dsp,bram_bits=bram,inv=inv)

def util_max(est):
    return max(est["luts"]/usable(DEV["luts"]),est["ffs"]/usable(DEV["ffs"]),
               est["dsps"]/usable(DEV["dsps"]),est["bram_bits"]/usable(DEV["bram_bits"]))

def body_latency(inv):
    return (inv["f_add"]*4+inv["f_mul"]*4+inv["f_div"]*28+inv["f_trig"]*36
            +(inv["loads"]+inv["stores"])*5+(inv["i_op"]+inv["cmp"])*1)

def schedule(loop_stmt, dep, est_combined_util, defines):
    inv=inventory(loop_stmt, defines)
    lat=max(body_latency(inv),1)
    mem_bound=max(math.ceil(inv["ports"]/4),1)
    if dep[0]=="independent": ii=mem_bound
    elif dep[0]=="reduction": ii=max(4,mem_bound)
    else: ii=max(lat,mem_bound)
    derate=1.0-0.28*est_combined_util**1.5
    fmax=DEV["clock"]*min(max(derate,0.4),1.0)
    return dict(ii=ii,depth=lat,fmax=fmax)

CPU=dict(clock=1.7e9,ipc=1.6,fadd=1.0,fmul=1.0,fdiv=14.0,trig=42.0,iop=0.5,cmp=0.5,rd=1.1,wr=1.4)
def cpu_time(ops):
    raw=(ops["f_add"]*CPU["fadd"]+ops["f_mul"]*CPU["fmul"]+ops["f_div"]*CPU["fdiv"]
         +ops["f_trig"]*CPU["trig"]+ops["i_op"]*CPU["iop"]+ops["cmp"]*CPU["cmp"]
         +ops["reads"]*CPU["rd"]+ops["writes"]*CPU["wr"])
    return raw/CPU["ipc"]/CPU["clock"]

def dma(bytes_):
    if bytes_==0: return 0.0
    return DEV["dma_lat"]+bytes_/DEV["pcie"]

TRIGW=24
def weighted_flops(o): return o["f_add"]+o["f_mul"]+o["f_div"]+o["f_trig"]*TRIGW

def run_model(src, verbose=True, top_a=5, top_c=3, first_round=3, max_patterns=4):
    prog=P(src).parse()
    interp=Interp(prog)
    interp.call("main")
    table=loop_table(prog)
    total=interp.total.asdict()
    # find loop stmts by id
    loops_by_id={}
    for fname in prog.funcorder:
        _,body=prog.funcs[fname]
        for s in body:
            def g(x):
                if x[0] in ("for","while"): loops_by_id[x[1]]=x
            walk_stmt(s,g)
    # intensity
    ranked=[]
    for lid,slot in enumerate(interp.slots):
        if slot["entries"]==0: continue
        work=weighted_flops(slot["ops"])
        acc=slot["ops"]["reads"]+slot["ops"]["writes"]
        inten=work/max(acc,1)
        ranked.append(dict(id=lid,work=work,acc=acc,inten=inten,score=inten*work,
                           trips=slot["trips"],entries=slot["entries"]))
    ranked.sort(key=lambda r:(-r["score"],-r["work"],r["id"]))
    # candidates
    def candidate(lid):
        return table[lid]["blocker"] is None and interp.slots[lid]["entries"]>0
    cand_ranked=[r for r in ranked if candidate(r["id"])]
    if verbose:
        print(f"loops: {prog.next_loop} | total cpu time {cpu_time(total)*1e3:.3f} ms")
        for r in cand_ranked[:8]:
            print(f"  cand L{r['id']:<3} score {r['score']:.3e} work {r['work']:.3e} inten {r['inten']:.2f} entries {r['entries']}")
    # split viability + kernel params
    def split_ok(lid):
        info=table[lid]
        # arrays must be global arrays
        garrs={}
        for (_,gn,ty,_) in prog.globals:
            if ty[0]=="arr": garrs[gn]=(ty[1],ty[2])
        arrays=[]
        for name in sorted(info["ar"]|info["aw"]):
            if name not in garrs: return None
            elem,dims=garrs[name]
            if name in info["ar"] and name in info["aw"]: d="inout"
            elif name in info["aw"]: d="out"
            else: d="in"
            arrays.append((name,elem,dims,d))
        gscal={gn for (_,gn,ty,_) in prog.globals if ty[0]=="scalar"}
        # free scalars: written -> must be global
        loop_stmt=loops_by_id[lid]
        written=set()
        body = loop_stmt[5] if loop_stmt[0]=="for" else loop_stmt[3]
        for st in body:
            def w(x):
                if x[0]=="assign" and x[1][0]=="var": written.add(x[1][1])
            walk_stmt(st,w)
        scal_params=[]
        for name in sorted(info["free"]):
            if name in written and name not in gscal:
                return None  # ScalarWriteback
            scal_params.append(name)
        return dict(arrays=arrays,scalars=scal_params)
    # funnel
    survivors=[]
    for r in cand_ranked[:top_a]:
        lid=r["id"]
        sp=split_ok(lid)
        if sp is None:
            if verbose: print(f"  split FAIL L{lid}")
            continue
        est=estimate(loops_by_id[lid],sp["arrays"],prog.defines)
        u=util_max(est)
        fits=u<=1.0
        eff=(r["inten"]/u) if u>0 else 0.0
        if verbose:
            print(f"  precompile L{lid}: util {u*100:.1f}% eff {eff:.1f} fits {fits} dsp {est['dsps']} lut {est['luts']}")
        if fits:
            survivors.append(dict(id=lid,est=est,eff=eff,inten=r,sp=sp))
    survivors.sort(key=lambda s:(-s["eff"],s["id"]))
    survivors=survivors[:top_c]
    if not survivors:
        print("NO CANDIDATES"); return None
    # subtree ids
    def subtree(lid):
        out=set([lid]); stk=[lid]
        while stk:
            c=stk.pop()
            for ch in table[c]["children"]:
                if ch not in out: out.add(ch); stk.append(ch)
        return out
    def simulate(pattern):
        # pattern: list of survivor dicts
        ids=[s["id"] for s in pattern]
        for s in pattern:
            st=subtree(s["id"])
            for o in ids:
                if o!=s["id"] and o in st: return None  # overlap
        comb=dict(luts=0,ffs=0,dsps=0,bram_bits=0)
        for s in pattern:
            for f in comb: comb[f]+=s["est"][f]
        cu=max(comb["luts"]/usable(DEV["luts"]),comb["ffs"]/usable(DEV["ffs"]),
               comb["dsps"]/usable(DEV["dsps"]),comb["bram_bits"]/usable(DEV["bram_bits"]))
        if cu>1.0: return None
        base=cpu_time(total)
        offops={f:0 for f in FIELDS}
        fpga=0.0
        detail=[]
        for s in pattern:
            lid=s["id"]
            lp=interp.slots[lid]
            for f in FIELDS: offops[f]+=lp["ops"][f]
            dep=classify(loops_by_id[lid])
            sched=schedule(loops_by_id[lid],dep,cu,prog.defines)
            entries=max(lp["entries"],1)
            inner_trips=max(interp.slots[i]["trips"] for i in subtree(lid))
            sf=spatial_factor(loops_by_id[lid],prog.defines)
            slots=max(math.ceil(inner_trips/sf),1)
            fill=entries*sched["depth"]/sched["fmax"]
            thr=slots*sched["ii"]/sched["fmax"]
            bin_=sum((size_of(e)*math.prod(d)) for (n,e,d,dr) in s["sp"]["arrays"] if dr in ("in","inout"))
            bin_+=4*len(s["sp"]["scalars"])
            bout=sum((size_of(e)*math.prod(d)) for (n,e,d,dr) in s["sp"]["arrays"] if dr in ("out","inout"))
            xfer=entries*(DEV["launch"]+dma(bin_)+dma(bout))
            fpga+=fill+thr+xfer
            detail.append((lid,dep[0],sched,entries,slots,sf,(fill+thr)*1e6,xfer*1e6))
        rest={f:max(total[f]-offops[f],0) for f in FIELDS}
        pat=cpu_time(rest)+fpga
        return dict(speedup=base/pat,detail=detail,pattern=[s['id'] for s in pattern],
                    rest_ms=cpu_time(rest)*1e3,fpga_us=fpga*1e6)
    measurements=[]
    accelerated=[]
    for s in survivors[:first_round]:
        m=simulate([s])
        if m is None:
            if verbose: print(f"  measure L{s['id']}: SIM FAIL")
            continue
        measurements.append(m)
        if m["speedup"]>1.0: accelerated.append(s)
        if verbose:
            print(f"  round1 L{s['id']}: {m['speedup']:.2f}x rest {m['rest_ms']:.3f}ms fpga {m['fpga_us']:.1f}us {m['detail']}")
    budget=max_patterns-len(measurements)
    if len(accelerated)>=2 and budget>0:
        import itertools
        combos=[]
        for r in range(2,len(accelerated)+1):
            for c in itertools.combinations(accelerated,r):
                m=simulate(list(c))
                if m is not None:
                    sc=sum(x["speedup"] for x in measurements if x["pattern"][0] in [y["id"] for y in c] and len(x["pattern"])==1)
                    combos.append((sc,m))
        combos.sort(key=lambda x:-x[0])
        for sc,m in combos[:budget]:
            measurements.append(m)
            if verbose: print(f"  round2 {m['pattern']}: {m['speedup']:.2f}x")
    best=max(measurements,key=lambda m:m["speedup"])
    print(f"BEST pattern {best['pattern']} speedup {best['speedup']:.2f}x | "
          f"measurements {len(measurements)} | baseline {cpu_time(total)*1e3:.3f} ms")
    return dict(best=best,measurements=measurements,total=total,interp=interp,prog=prog)

if __name__=="__main__":
    src=open(sys.argv[1]).read()
    run_model(src)
