#!/usr/bin/env python3
"""CI regression gate for the hot-path bench series.

Usage: python3 tools/bench_gate.py <BENCH_hotpath.json> <baseline.json>

The baseline maps speedup-series names (higher is better) to their
committed floor. The gate fails if any current value drops below
95% of its floor — enough slack to absorb runner jitter while still
catching a real dispatch-loop regression. Raise the floors when a
change lands that durably improves a series.
"""
import json
import sys

SLACK = 0.95

def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__.strip())
    results = json.load(open(sys.argv[1]))
    baseline = json.load(open(sys.argv[2]))
    failures = []
    for key, floor in sorted(baseline.items()):
        got = results.get(key)
        if got is None:
            failures.append(f"{key}: missing from results")
            continue
        limit = floor * SLACK
        verdict = "ok" if got >= limit else "REGRESSION"
        print(f"{key}: {got:.2f}x (floor {floor:.2f}x, limit {limit:.2f}x) {verdict}")
        if got < limit:
            failures.append(f"{key}: {got:.2f}x < {limit:.2f}x")
    if failures:
        sys.exit("bench gate failed:\n  " + "\n  ".join(failures))
    print("bench gate: PASS")

if __name__ == "__main__":
    main()
